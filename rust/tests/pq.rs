//! Integration: product-quantized serving — the single-file DSP1
//! round trip through the auto-detecting reader, rank correlation of
//! per-query ADC lookup-table distances against exact f32, and the PQ
//! serving grid (Shard-owned vs Block-paged bit-identity across
//! probe x budget x rerank, `rerank=4` recall within 2 points of the
//! f32 index, per-row footprint below scalar quantization).

use std::collections::HashSet;
use std::path::PathBuf;

use gnnd::dataset::{groundtruth, io, synth, Dataset};
use gnnd::gnnd::{GnndParams, NativeEngine};
use gnnd::merge::outofcore::{
    build_out_of_core, pq_quantize_store, OutOfCoreConfig, ResidencyMode, ShardCompression,
    ShardStore,
};
use gnnd::search::sharded::ShardedIndex;
use gnnd::search::{AnnIndex, SearchParams};
use gnnd::telemetry;

fn tmpdir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!(
        "gnnd-pq-{tag}-{}-{:x}",
        std::process::id(),
        std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .unwrap()
            .as_nanos()
    ));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn recall_with_f32_queries(
    index: &dyn AnnIndex,
    ds: &Dataset,
    qids: &[usize],
    truth: &[Vec<u32>],
    k: usize,
) -> f64 {
    let mut scratch = index.make_scratch();
    let mut out = Vec::new();
    let mut hit = 0usize;
    let mut total = 0usize;
    for (row, &q) in truth.iter().zip(qids) {
        index.search_ef_into_excluding(ds.vec(q), k, 0, q as u32, &mut scratch, &mut out);
        let set: HashSet<u32> = out.iter().map(|&(_, id)| id).collect();
        hit += row.iter().take(k).filter(|id| set.contains(id)).count();
        total += row.len().min(k);
    }
    hit as f64 / total as f64
}

/// A `.dsb` written by `write_dsb_pq` comes back through the plain
/// auto-detecting reader as a PQ-backed dataset whose ADC distances
/// equal the exact distance to the reconstructed row, and whose
/// stored row footprint undercuts both f32 and u8 scalar codes.
#[test]
fn pq_file_roundtrip_auto_detects_and_matches_reconstruction() {
    let ds = synth::clustered(300, 8, 61);
    let dir = tmpdir("roundtrip");
    let path = dir.join("pq.dsb");
    io::write_dsb_pq(&ds, 4, &path).unwrap();
    let pq = io::read_dsb(&path).unwrap();
    assert!(pq.is_pq() && pq.is_compressed());
    assert_eq!(pq.backing_kind(), "pq");
    assert_eq!((pq.len(), pq.d), (ds.len(), ds.d));

    // m bytes/row beats the d bytes of scalar quant and 4d of f32
    assert_eq!(pq.stored_row_bytes(), 4);
    assert!(pq.stored_row_bytes() < ds.quantize().stored_row_bytes());
    assert!(ds.quantize().stored_row_bytes() < ds.stored_row_bytes());

    // the LUT is an exact decomposition: summing m table entries must
    // reproduce the full-precision distance to the reconstruction
    let mut qcodes = Vec::new();
    let mut lut = Vec::new();
    for q in (0..ds.len()).step_by(29) {
        let qv = ds.vec(q).to_vec();
        assert!(pq.prepare_query(&qv, &mut qcodes, &mut lut), "PQ backing must build a LUT");
        assert!(qcodes.is_empty(), "PQ queries use the LUT, not u8 codes");
        for i in (0..ds.len()).step_by(17) {
            let adc = pq.dist_to_quant(i, &qv, &qcodes, &lut);
            let recon = pq.dist_to(i, &qv); // decodes the row, exact distance
            let tol = 1e-3 * recon.abs().max(1.0);
            assert!(
                (adc - recon).abs() <= tol,
                "ADC {adc} != reconstruction distance {recon} (q={q} i={i})"
            );
        }
    }
    std::fs::remove_dir_all(dir).ok();
}

/// PQ code-space distances preserve the f32 neighbor ordering: over
/// sampled candidate pairs whose exact distances differ by more than
/// the quantization noise floor, the LUT distance agrees on the order
/// — the rank correlation that lets a PQ beam plus exact rerank
/// recover f32 recall.
#[test]
fn pq_rank_correlation_with_f32() {
    let ds = synth::clustered(300, 8, 52);
    let dir = tmpdir("rankcorr");
    let path = dir.join("pq.dsb");
    io::write_dsb_pq(&ds, 4, &path).unwrap();
    let pq = io::read_dsb(&path).unwrap();
    let mut qcodes = Vec::new();
    let mut lut = Vec::new();
    let (mut concordant, mut pairs) = (0usize, 0usize);
    for q in (0..ds.len()).step_by(11) {
        let qv = ds.vec(q).to_vec();
        assert!(pq.prepare_query(&qv, &mut qcodes, &mut lut), "PQ backing must build a LUT");
        for i in (0..ds.len()).step_by(7) {
            let j = (i * 131 + 17) % ds.len();
            let (di, dj) = (ds.dist_to(i, &qv), ds.dist_to(j, &qv));
            if (di - dj).abs() <= 0.05 * di.abs().max(dj.abs()).max(1e-6) {
                continue;
            }
            let qi = pq.dist_to_quant(i, &qv, &qcodes, &lut);
            let qj = pq.dist_to_quant(j, &qv, &qcodes, &lut);
            pairs += 1;
            if (di < dj) == (qi < qj) {
                concordant += 1;
            }
        }
    }
    assert!(pairs > 500, "tie filter ate the sample: only {pairs} pairs");
    let frac = concordant as f64 / pairs as f64;
    assert!(frac >= 0.9, "rank concordance {frac:.3} over {pairs} pairs too low");
}

/// The PQ serving grid, mirroring the scalar-quant one: Shard-owned
/// and Block-paged residency are *bit-identical* across
/// probe x budget x rerank (same codes, same shared LUT, same
/// exact-rerank rows), `rerank=4` recovers to within 2 recall points
/// of the f32 index over the same shard directory, and loading the PQ
/// sidecars advances the `pq.bytes_saved` telemetry counter.
#[test]
fn pq_parity_grid_and_rerank_recall() {
    let ds = synth::clustered(480, 8, 54);
    let params = GnndParams::default().with_k(10).with_p(5).with_iters(6);
    let cfg = OutOfCoreConfig { shards: 4, workers: 2, params };
    let dir = tmpdir("grid");
    build_out_of_core(&ds, &dir, &cfg, &NativeEngine).unwrap();
    let pp = pq_quantize_store(&dir, 4).unwrap();
    assert_eq!((pp.d(), pp.m()), (8, 4));
    let manifest = ShardStore::new(&dir).unwrap().load_manifest().unwrap();
    let half = manifest.estimated_resident_bytes() / 2;

    let (qids, truth) = groundtruth::sampled_truth(&ds, 120, 10, 13);
    let f32_recall = {
        let idx = ShardedIndex::open(&dir, SearchParams::default().with_ef(48), 0).unwrap();
        recall_with_f32_queries(&idx, &ds, &qids, &truth, 10)
    };

    let saved_before = telemetry::global().counter("pq.bytes_saved").get();
    for rerank in [1usize, 4] {
        let sp = SearchParams::default().with_ef(48).with_rerank(rerank);
        for probe in [0usize, 2] {
            for budget in [0usize, half] {
                let owned = ShardedIndex::from_store(
                    ShardStore::with_compression(
                        &dir,
                        budget,
                        ResidencyMode::Shard,
                        ShardCompression::Pq,
                    )
                    .unwrap(),
                    sp.clone(),
                    probe,
                    1,
                )
                .unwrap();
                let paged = ShardedIndex::from_store(
                    ShardStore::with_compression(
                        &dir,
                        budget,
                        ResidencyMode::block(),
                        ShardCompression::Pq,
                    )
                    .unwrap(),
                    sp.clone(),
                    probe,
                    1,
                )
                .unwrap();
                assert!(
                    owned.describe().contains("pq(rerank="),
                    "describe must surface the backing: {}",
                    owned.describe()
                );
                let mut s_own = owned.make_scratch();
                let mut s_pg = paged.make_scratch();
                let (mut o_own, mut o_pg) = (Vec::new(), Vec::new());
                for q in (0..ds.len()).step_by(37) {
                    owned.search_ef_into_excluding(
                        ds.vec(q),
                        10,
                        0,
                        q as u32,
                        &mut s_own,
                        &mut o_own,
                    );
                    paged.search_ef_into_excluding(
                        ds.vec(q),
                        10,
                        0,
                        q as u32,
                        &mut s_pg,
                        &mut o_pg,
                    );
                    assert_eq!(
                        o_own, o_pg,
                        "PQ residency modes diverged (rerank={rerank} probe={probe} \
                         budget={budget}) on query {q}"
                    );
                    assert_eq!(
                        s_own.dist_evals, s_pg.dist_evals,
                        "LUT eval counts diverged on query {q}"
                    );
                    assert_eq!(
                        s_own.rerank_evals, s_pg.rerank_evals,
                        "rerank eval counts diverged on query {q}"
                    );
                    if rerank == 1 {
                        assert_eq!(s_own.rerank_evals, 0, "rerank=1 must skip the exact pass");
                    } else {
                        assert!(
                            s_own.rerank_evals > 0 && s_own.rerank_evals <= 10 * rerank,
                            "rerank pass must score at most rerank*k candidates: {}",
                            s_own.rerank_evals
                        );
                    }
                }
            }
        }
        let idx = ShardedIndex::from_store(
            ShardStore::with_compression(&dir, 0, ResidencyMode::Shard, ShardCompression::Pq)
                .unwrap(),
            SearchParams::default().with_ef(48).with_rerank(rerank),
            0,
            1,
        )
        .unwrap();
        let r = recall_with_f32_queries(&idx, &ds, &qids, &truth, 10);
        if rerank == 4 {
            assert!(
                r >= f32_recall - 0.02,
                "PQ rerank=4 recall {r} more than 2 points below f32 {f32_recall}"
            );
        } else {
            assert!(r > 0.5, "PQ rerank=1 recall collapsed outright: {r}");
        }
    }
    // every PQ shard load saves n*(4d - m) bytes over f32; at least
    // one full set of loads happened above
    let saved = telemetry::global().counter("pq.bytes_saved").get() - saved_before;
    assert!(
        saved >= (ds.len() * (4 * ds.d - 4)) as u64,
        "pq.bytes_saved advanced only {saved}"
    );
    std::fs::remove_dir_all(dir).ok();
}

/// `--quantize` parses the widened compression vocabulary and the
/// legacy booleans identically.
#[test]
fn shard_compression_parses_legacy_and_new_spellings() {
    assert_eq!("f32".parse::<ShardCompression>().unwrap(), ShardCompression::F32);
    assert_eq!("false".parse::<ShardCompression>().unwrap(), ShardCompression::F32);
    assert_eq!("scalar".parse::<ShardCompression>().unwrap(), ShardCompression::Scalar);
    assert_eq!("true".parse::<ShardCompression>().unwrap(), ShardCompression::Scalar);
    assert_eq!("pq".parse::<ShardCompression>().unwrap(), ShardCompression::Pq);
    assert!("zstd".parse::<ShardCompression>().is_err());
}
