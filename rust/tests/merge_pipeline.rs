//! Integration: GGM merge, incremental ingestion and the out-of-core
//! pipeline at medium scale with concurrent merge workers.

use gnnd::dataset::{groundtruth, synth};
use gnnd::gnnd::{build, GnndParams, NativeEngine};
use gnnd::merge::outofcore::{build_out_of_core, OutOfCoreConfig};
use gnnd::merge::{incremental_add, merge};
use gnnd::metrics::recall_at;

fn tmpdir(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!(
        "gnnd-it-{tag}-{}-{:x}",
        std::process::id(),
        std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .unwrap()
            .as_nanos()
    ));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

#[test]
fn ggm_merge_beats_padded_halves_on_sift_like() {
    let ds = synth::sift_like(2_000, 31);
    let n1 = 1_000;
    let params = GnndParams::default().with_k(16).with_p(8).with_iters(8);
    let ids1: Vec<usize> = (0..n1).collect();
    let ids2: Vec<usize> = (n1..2_000).collect();
    let g1 = build(&ds.select(&ids1, "h1"), &params).unwrap();
    let g2 = build(&ds.select(&ids2, "h2"), &params).unwrap();
    let (g, _) = merge(&ds, n1, &g1, &g2, &params, &NativeEngine).unwrap();
    g.check_invariants().unwrap();
    let (ids, truth) = groundtruth::sampled_truth(&ds, 500, 10, 8);
    let r = recall_at(&g, &truth, Some(&ids), 10);
    let mut g2r = g2.clone();
    g2r.remap_ids(|id| id + n1 as u32);
    let naive = g1.stack(&g2r);
    let rn = recall_at(&naive, &truth, Some(&ids), 10);
    assert!(r > 0.85, "merged recall {r}");
    // the paper's Fig. 7 gap (GGM regains the cross-subset neighbors)
    assert!(r > rn + 0.1, "merge gain too small: {r} vs naive {rn}");
}

#[test]
fn out_of_core_with_workers_and_odd_shards() {
    let ds = synth::clustered(1_500, 8, 32);
    let params = GnndParams::default().with_k(12).with_p(6).with_iters(6);
    // odd shard count exercises the tournament bye slot
    let cfg = OutOfCoreConfig { shards: 5, workers: 3, params: params.clone() };
    let dir = tmpdir("odd");
    let (g, stats) = build_out_of_core(&ds, &dir, &cfg, &NativeEngine).unwrap();
    std::fs::remove_dir_all(&dir).ok();
    assert_eq!(g.n(), 1_500);
    g.check_invariants().unwrap();
    assert_eq!(stats.merges, 10); // C(5,2)
    let (ids, truth) = groundtruth::sampled_truth(&ds, 400, 10, 9);
    let r = recall_at(&g, &truth, Some(&ids), 10);
    assert!(r > 0.85, "odd-shard out-of-core recall {r}");
}

#[test]
fn incremental_ingestion_stays_healthy_over_batches() {
    let full = synth::clustered(1_200, 8, 33);
    let params = GnndParams::default().with_k(12).with_p(6).with_iters(6);
    let step = 400;
    let ids0: Vec<usize> = (0..step).collect();
    let mut graph = build(&full.select(&ids0, "b0"), &params).unwrap();
    let mut have = step;
    while have < full.len() {
        let upto = (have + step).min(full.len());
        let ids: Vec<usize> = (0..upto).collect();
        let cur = full.select(&ids, "cur");
        let (g, _) = incremental_add(&cur, have, &graph, &params, &NativeEngine).unwrap();
        graph = g;
        graph.check_invariants().unwrap();
        have = upto;
    }
    let (ids, truth) = groundtruth::sampled_truth(&full, 400, 10, 10);
    let r = recall_at(&graph, &truth, Some(&ids), 10);
    assert!(r > 0.85, "incremental final recall {r}");
}

#[test]
fn merge_preserves_within_subset_quality() {
    // objects whose true neighbors are all within their own subset must
    // not lose them during merge
    let ds = synth::clustered(800, 6, 34);
    let n1 = 400;
    let params = GnndParams::default().with_k(10).with_p(5).with_iters(6);
    let ids1: Vec<usize> = (0..n1).collect();
    let ids2: Vec<usize> = (n1..800).collect();
    let g1 = build(&ds.select(&ids1, "h1"), &params).unwrap();
    let g2 = build(&ds.select(&ids2, "h2"), &params).unwrap();
    let phi_before = g1.phi() + g2.phi();
    let (g, _) = merge(&ds, n1, &g1, &g2, &params, &NativeEngine).unwrap();
    assert!(g.phi() <= phi_before + 1e-6, "merge made lists worse overall");
}
