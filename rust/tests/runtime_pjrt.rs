//! Integration: the PJRT runtime against the native oracle.
//!
//! These tests are the Rust-side half of the L1/L2 correctness story:
//! python/tests pin kernel-vs-ref and model semantics; here the *same
//! AOT artifacts* must agree with the bit-compatible native engine when
//! driven by the real coordinator. Skipped (with a note) when
//! `artifacts/` has not been built.

use gnnd::config::{EngineKind, Metric};
use gnnd::dataset::{groundtruth, synth};
use gnnd::gnnd::engine::{Batch, CrossmatchEngine, NativeEngine};
use gnnd::gnnd::{build_with_stats, GnndParams};
use gnnd::graph::EMPTY;
use gnnd::metrics::recall_at;
use gnnd::runtime::{artifacts_available, BruteforceExec, PjrtEngine};
use gnnd::util::rng::Rng;

const DIR: &str = "artifacts";

fn need_artifacts() -> bool {
    if artifacts_available(DIR) {
        true
    } else {
        eprintln!("SKIP: artifacts/ not built (run `make artifacts`)");
        false
    }
}

#[test]
fn pjrt_crossmatch_matches_native_oracle() {
    if !need_artifacts() {
        return;
    }
    let ds = synth::sift_like(500, 21);
    let engine = PjrtEngine::load(DIR, 32, ds.d, Metric::L2).unwrap();
    let mut rng = Rng::new(7);
    let rows = 20;
    let s = 32;
    let mut new_ids = Vec::new();
    let mut old_ids = Vec::new();
    for _ in 0..rows * s {
        // include empty slots
        let a = rng.below(ds.len() + 50);
        new_ids.push(if a >= ds.len() { EMPTY } else { a as u32 });
        let b = rng.below(ds.len() + 50);
        old_ids.push(if b >= ds.len() { EMPTY } else { b as u32 });
    }
    let to_g = |v: &Vec<u32>| -> Vec<i32> {
        v.iter().map(|&x| if x == EMPTY { -1 } else { x as i32 }).collect()
    };
    let (gn, go) = (to_g(&new_ids), to_g(&old_ids));
    let batch = Batch { s, rows, new_ids: &new_ids, old_ids: &old_ids, groups_new: &gn, groups_old: &go };
    let a = engine.crossmatch(&ds, &batch).unwrap();
    let b = NativeEngine.crossmatch(&ds, &batch).unwrap();
    let mut checked = 0;
    for i in 0..rows * s {
        // sentinels must agree exactly
        assert_eq!(a.nn_idx[i] < 0, b.nn_idx[i] < 0, "nn sentinel i={i}");
        assert_eq!(a.no_idx[i] < 0, b.no_idx[i] < 0, "no sentinel i={i}");
        assert_eq!(a.on_idx[i] < 0, b.on_idx[i] < 0, "on sentinel i={i}");
        // distances must agree to f32 tolerance (winner ids may differ
        // on near-ties between the matmul-form and scalar distance)
        for (da, db, tag) in [
            (a.nn_dist[i], b.nn_dist[i], "nn"),
            (a.no_dist[i], b.no_dist[i], "no"),
            (a.on_dist[i], b.on_dist[i], "on"),
        ] {
            if da.is_finite() || db.is_finite() {
                let tol = 1e-2 * db.abs().max(1.0);
                assert!((da - db).abs() <= tol, "{tag} i={i}: pjrt={da} native={db}");
                checked += 1;
            }
        }
    }
    assert!(checked > rows * s, "suspiciously few finite results ({checked})");
}

#[test]
fn pjrt_engine_builds_a_good_graph() {
    if !need_artifacts() {
        return;
    }
    let ds = synth::sift_like(1_200, 22);
    let params = GnndParams::default()
        .with_k(16)
        .with_p(8)
        .with_iters(6)
        .with_engine(EngineKind::Pjrt);
    let out = build_with_stats(&ds, &params).unwrap();
    assert_eq!(out.stats.engine, "pjrt");
    out.graph.check_invariants().unwrap();
    let (ids, truth) = groundtruth::sampled_truth(&ds, 300, 10, 5);
    let r = recall_at(&out.graph, &truth, Some(&ids), 10);
    assert!(r > 0.85, "pjrt-engine recall@10 = {r}");
}

#[test]
fn pjrt_and_native_engines_agree_on_final_quality() {
    if !need_artifacts() {
        return;
    }
    let ds = synth::deep_like(800, 23);
    let (ids, truth) = groundtruth::sampled_truth(&ds, 300, 10, 6);
    let mut rs = Vec::new();
    for engine in [EngineKind::Native, EngineKind::Pjrt] {
        let params = GnndParams::default()
            .with_k(16)
            .with_p(8)
            .with_iters(6)
            .with_engine(engine);
        let out = build_with_stats(&ds, &params).unwrap();
        rs.push(recall_at(&out.graph, &truth, Some(&ids), 10));
    }
    assert!(
        (rs[0] - rs[1]).abs() < 0.06,
        "native {} vs pjrt {} recall divergence",
        rs[0],
        rs[1]
    );
}

#[test]
fn pjrt_bruteforce_matches_exact_truth() {
    if !need_artifacts() {
        return;
    }
    let ds = synth::sift_like(700, 24);
    let exec = BruteforceExec::load(DIR, ds.d, Metric::L2).unwrap();
    let qids: Vec<usize> = (0..40).collect();
    let got = exec.topk(&ds, &qids, 10).unwrap();
    let want = groundtruth::exact_topk_for(&ds, &qids, 10);
    for (q, (g, w)) in got.iter().zip(&want).enumerate() {
        // compare by distances (id ties allowed)
        let gd: Vec<f32> = g.iter().map(|&id| ds.dist(qids[q], id as usize)).collect();
        let wd: Vec<f32> = w.iter().map(|&id| ds.dist(qids[q], id as usize)).collect();
        assert_eq!(gd.len(), wd.len(), "q={q}");
        for (a, b) in gd.iter().zip(&wd) {
            assert!((a - b).abs() <= 1e-2 * b.max(1.0), "q={q}: {gd:?} vs {wd:?}");
        }
    }
}

#[test]
fn cosine_metric_routes_to_ip_artifact() {
    if !need_artifacts() {
        return;
    }
    let ds = synth::glove_like(600, 25);
    let params = GnndParams::default()
        .with_k(12)
        .with_p(6)
        .with_iters(5)
        .with_engine(EngineKind::Pjrt);
    let out = build_with_stats(&ds, &params).unwrap();
    let (ids, truth) = groundtruth::sampled_truth(&ds, 200, 10, 7);
    let r = recall_at(&out.graph, &truth, Some(&ids), 10);
    assert!(r > 0.75, "cosine via ip artifact recall {r}");
}
