//! Integration: the full native construction pipeline across modules —
//! dataset -> GNND (all update strategies) -> recall/phi evaluation.

use gnnd::config::UpdateStrategy;
use gnnd::dataset::{groundtruth, synth};
use gnnd::gnnd::{build, build_with_stats, GnndParams};
use gnnd::metrics::recall_at;

#[test]
fn sift_like_reaches_high_recall() {
    let ds = synth::sift_like(3_000, 11);
    let params = GnndParams::default().with_k(20).with_p(10).with_iters(10);
    let out = build_with_stats(&ds, &params).unwrap();
    out.graph.check_invariants().unwrap();
    let (ids, truth) = groundtruth::sampled_truth(&ds, 500, 10, 1);
    let r = recall_at(&out.graph, &truth, Some(&ids), 10);
    assert!(r > 0.95, "sift-like recall@10 = {r}");
    // distance evaluation must dominate the coordinator phases (the
    // paper: >90% of NN-Descent time is distance calculation; we accept
    // a softer 50% for the native engine with all coordinator overheads)
    let phases = &out.stats.phases;
    let total: f64 = phases.iter().map(|(_, s)| s).sum();
    let xmatch: f64 = phases
        .iter()
        .filter(|(n, _)| *n == "2.crossmatch")
        .map(|(_, s)| s)
        .sum();
    assert!(
        xmatch / total > 0.5,
        "crossmatch share {:.2} too low ({phases:?})",
        xmatch / total
    );
}

#[test]
fn glove_cosine_works_end_to_end() {
    let ds = synth::glove_like(2_000, 12);
    let params = GnndParams::default().with_k(16).with_p(8).with_iters(10);
    let g = build(&ds, &params).unwrap();
    g.check_invariants().unwrap();
    let (ids, truth) = groundtruth::sampled_truth(&ds, 400, 10, 2);
    let r = recall_at(&g, &truth, Some(&ids), 10);
    assert!(r > 0.8, "glove-like cosine recall@10 = {r}");
}

#[test]
fn gist_like_high_dim_works() {
    let ds = synth::gist_like(800, 13);
    let params = GnndParams::default().with_k(16).with_p(8).with_iters(8);
    let g = build(&ds, &params).unwrap();
    let (ids, truth) = groundtruth::sampled_truth(&ds, 300, 10, 3);
    let r = recall_at(&g, &truth, Some(&ids), 10);
    assert!(r > 0.8, "gist-like recall@10 = {r} (d=960, low intrinsic dim)");
}

#[test]
fn strategies_agree_on_quality_but_segment_correctly() {
    let ds = synth::clustered(1_500, 8, 14);
    let (ids, truth) = groundtruth::sampled_truth(&ds, 400, 10, 4);
    let mut recalls = Vec::new();
    for update in [
        UpdateStrategy::InsertAll,
        UpdateStrategy::SelectiveSingleLock,
        UpdateStrategy::SelectiveSegmented,
    ] {
        let params = GnndParams::default()
            .with_k(32)
            .with_p(16)
            .with_iters(8)
            .with_update(update);
        let g = build(&ds, &params).unwrap();
        g.check_invariants().unwrap();
        recalls.push((update, recall_at(&g, &truth, Some(&ids), 10)));
    }
    for (u, r) in &recalls {
        assert!(*r > 0.9, "{u:?}: recall {r}");
    }
    // selective update must not lose meaningful quality vs insert-all
    let r1 = recalls[0].1;
    let full = recalls[2].1;
    assert!(full > r1 - 0.05, "selective lost too much: {full} vs {r1}");
}

#[test]
fn updates_decay_across_iterations() {
    let ds = synth::clustered(1_000, 8, 15);
    let params = GnndParams::default().with_k(16).with_p(8).with_iters(12);
    let out = build_with_stats(&ds, &params).unwrap();
    let u = &out.stats.updates;
    assert!(u.len() >= 3, "terminated too early: {u:?}");
    // the hill-climb must slow down monotonically-ish: last < first/4
    assert!(
        *u.last().unwrap() < u[0] / 4,
        "updates did not decay: {u:?}"
    );
}
