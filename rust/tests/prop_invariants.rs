//! Cross-module property tests (the DESIGN.md invariant list), using
//! the crate's seeded mini-prop harness (`gnnd::util::prop`).

use std::sync::Arc;

use gnnd::config::{GnndParams, UpdateStrategy};
use gnnd::dataset::{groundtruth, synth};
use gnnd::gnnd::engine::{Batch, CrossmatchEngine, NativeEngine};
use gnnd::gnnd::{build_with_stats, sample::parallel_sample};
use gnnd::graph::{KnnGraph, EMPTY};
use gnnd::merge::outofcore::{ResidencyStats, ResidentShard, ShardStore};
use gnnd::metrics::recall_at;
use gnnd::util::json::Json;
use gnnd::util::{prop, rng::Rng};

#[test]
fn prop_recall_bounded_and_exact_graph_is_one() {
    prop::check("recall-bounds", 8, |rng| {
        let n = 60 + rng.below(60);
        let ds = synth::uniform(n, 4, rng.next_u64());
        let k = 3 + rng.below(5);
        let truth = groundtruth::exact_topk(&ds, k);
        let g = crate_build_exact(&ds, &truth, k);
        let r = recall_at(&g, &truth, None, k);
        prop::assert_prop((r - 1.0).abs() < 1e-9, format!("exact graph recall {r}"))?;
        let mut rng2 = Rng::new(rng.next_u64());
        let rand_g = KnnGraph::random_init(&ds, k, &mut rng2);
        let rr = recall_at(&rand_g, &truth, None, k);
        prop::assert_prop((0.0..=1.0).contains(&rr), format!("recall out of bounds {rr}"))
    });
}

fn crate_build_exact(ds: &gnnd::Dataset, truth: &[Vec<u32>], k: usize) -> KnnGraph {
    let mut g = KnnGraph::empty(ds.len(), k);
    for (u, row) in truth.iter().enumerate() {
        for &v in row.iter().take(k) {
            g.insert(u, v, ds.dist(u, v as usize), false);
        }
    }
    g
}

#[test]
fn prop_phi_never_increases_under_any_strategy() {
    prop::check("phi-monotone", 6, |rng| {
        let n = 150 + rng.below(150);
        let ds = synth::clustered(n, 6, rng.next_u64());
        let strat = match rng.below(3) {
            0 => UpdateStrategy::InsertAll,
            1 => UpdateStrategy::SelectiveSingleLock,
            _ => UpdateStrategy::SelectiveSegmented,
        };
        let mut params = GnndParams::default()
            .with_k(4 + rng.below(12))
            .with_iters(5)
            .with_update(strat)
            .with_seed(rng.next_u64());
        params.p = (params.k / 2).max(1);
        params.trace_phi = true;
        let out = build_with_stats(&ds, &params).map_err(|e| e.to_string())?;
        for w in out.stats.phi_trace.windows(2) {
            prop::assert_prop(
                w[1] <= w[0] + 1e-6,
                format!("phi increased under {strat:?}: {:?}", out.stats.phi_trace),
            )?;
        }
        out.graph.check_invariants().map_err(|e| e.to_string())
    });
}

#[test]
fn prop_sampling_bounds_hold_for_all_p() {
    prop::check("sampling-bounds", 10, |rng| {
        let n = 50 + rng.below(100);
        let k = 4 + rng.below(12);
        let p = 1 + rng.below(k);
        let ds = synth::uniform(n, 4, rng.next_u64());
        let mut g = KnnGraph::random_init(&ds, k.min(n - 1), &mut Rng::new(rng.next_u64()));
        let lists = parallel_sample(&mut g, p, 1 + rng.below(4));
        for u in 0..n {
            let live_new = lists.new_row(u).iter().filter(|&&x| x != EMPTY).count();
            let live_old = lists.old_row(u).iter().filter(|&&x| x != EMPTY).count();
            prop::assert_prop(live_new <= 2 * p, format!("u={u} new {live_new} > 2p"))?;
            prop::assert_prop(live_old <= 2 * p, format!("u={u} old {live_old} > 2p"))?;
            // no duplicates, no self
            let mut seen = std::collections::HashSet::new();
            for &v in lists.new_row(u).iter().filter(|&&x| x != EMPTY) {
                prop::assert_prop(v as usize != u, "self-sample")?;
                prop::assert_prop(seen.insert(v), "duplicate sample")?;
            }
        }
        Ok(())
    });
}

#[test]
fn prop_crossmatch_winner_is_true_minimum() {
    prop::check("crossmatch-argmin", 20, |rng| {
        let n = 40 + rng.below(60);
        let ds = synth::uniform(n, 3 + rng.below(8), rng.next_u64());
        let s = 2 + rng.below(10);
        let rows = 1 + rng.below(4);
        let mut new_ids = Vec::new();
        let mut old_ids = Vec::new();
        for _ in 0..rows * s {
            new_ids.push(rng.below(n) as u32);
            old_ids.push(rng.below(n) as u32);
        }
        let gn: Vec<i32> = new_ids.iter().map(|&x| x as i32).collect();
        let go: Vec<i32> = old_ids.iter().map(|&x| x as i32).collect();
        let batch = Batch { s, rows, new_ids: &new_ids, old_ids: &old_ids, groups_new: &gn, groups_old: &go };
        let out = NativeEngine.crossmatch(&ds, &batch).map_err(|e| e.to_string())?;
        for r in 0..rows {
            for i in 0..s {
                let li = r * s + i;
                let u = new_ids[li];
                // check the no winner against a brute scan
                let mut best = f32::INFINITY;
                for j in 0..s {
                    let v = old_ids[r * s + j];
                    if v != u {
                        best = best.min(ds.dist(u as usize, v as usize));
                    }
                }
                if out.no_idx[li] >= 0 {
                    prop::assert_prop(
                        (out.no_dist[li] - best).abs() < 1e-4 * best.max(1.0),
                        format!("no winner {} != min {best}", out.no_dist[li]),
                    )?;
                } else {
                    prop::assert_prop(best.is_infinite(), "missed a valid old pair")?;
                }
            }
        }
        Ok(())
    });
}

/// Residency invariants of the serving-side [`ShardStore`] cache under
/// seeded-random op sequences (get / hold pin / drop pin / evict):
///
/// * `resident_bytes <= budget` whenever no pins are held (after an
///   eviction pass — pins legitimately push the cache past the budget
///   while they live);
/// * `hits + misses` equals the number of `get_shard` calls, at every
///   point in the sequence;
/// * evictions never touch pinned shards: re-getting a shard whose
///   handle is still held *and was admitted to the cache* is always a
///   cache hit (the two-visit doorkeeper may serve a shard without
///   caching it — those handles stay readable but are legitimately
///   re-loaded on the next get);
/// * the counters survive a `to_json`/`from_json` round trip.
#[test]
fn prop_shard_store_residency_invariants() {
    // one on-disk shard dir shared by every case (cases only read)
    let dir = std::env::temp_dir().join(format!(
        "gnnd-prop-store-{}-{:x}",
        std::process::id(),
        std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .unwrap()
            .as_nanos()
    ));
    std::fs::create_dir_all(&dir).unwrap();
    let shards = 5usize;
    {
        let store = ShardStore::new(&dir).unwrap();
        for i in 0..shards {
            // identical geometry -> identical byte cost per shard, so a
            // budget of m*one fits exactly m shards
            store.save_shard(i, &synth::uniform(40, 4, 900 + i as u64)).unwrap();
            store.save_graph(i, &KnnGraph::empty(40, 6)).unwrap();
        }
    }
    let one = ShardStore::new(&dir).unwrap().get_shard(0).unwrap().bytes;

    prop::check("shard-store-residency", 12, |rng| {
        let budget = one * (1 + rng.below(shards));
        let store = ShardStore::with_budget(&dir, budget).map_err(|e| e.to_string())?;
        let mut held: Vec<(usize, Arc<ResidentShard>)> = Vec::new();
        let mut gets = 0u64;
        for _ in 0..60 {
            match rng.below(10) {
                0..=4 => {
                    let s = rng.below(shards);
                    let rejected_before = store.residency().rejected_admissions;
                    let h = store.get_shard(s).map_err(|e| e.to_string())?;
                    gets += 1;
                    // only admitted (or hit) shards are guaranteed to
                    // stay resident while pinned — a doorkeeper-rejected
                    // handle is served without being cached
                    let admitted =
                        store.residency().rejected_admissions == rejected_before;
                    if admitted && rng.below(2) == 0 {
                        held.push((s, h));
                    }
                }
                5 => {
                    if !held.is_empty() {
                        let i = rng.below(held.len());
                        held.swap_remove(i);
                    }
                }
                6 => store.evict_to_budget(),
                7..=8 => {
                    // a held pin must never have been evicted: re-get
                    // is a hit, and the handle still reads coherently
                    if !held.is_empty() {
                        let (s, ref h) = held[rng.below(held.len())];
                        let before = store.residency().hits;
                        let again = store.get_shard(s).map_err(|e| e.to_string())?;
                        gets += 1;
                        prop::assert_prop(
                            store.residency().hits == before + 1,
                            format!("pinned shard {s} was evicted out of the cache"),
                        )?;
                        prop::assert_prop(
                            again.ds.raw() == h.ds.raw(),
                            format!("pinned shard {s} re-read with different data"),
                        )?;
                    }
                }
                _ => {
                    let r = store.residency();
                    prop::assert_prop(
                        r.hits + r.misses == gets,
                        format!("hits {} + misses {} != {gets} get_shard calls", r.hits, r.misses),
                    )?;
                }
            }
        }
        // with every pin released, one eviction pass restores the
        // budget invariant exactly
        held.clear();
        store.evict_to_budget();
        let r = store.residency();
        prop::assert_prop(
            r.resident_bytes <= budget,
            format!("resident {} > budget {budget} with no pins held", r.resident_bytes),
        )?;
        prop::assert_prop(r.hits + r.misses == gets, "final get_shard accounting")?;
        prop::assert_prop(
            r.peak_resident_bytes >= r.resident_bytes,
            "peak below current residency",
        )?;
        // counters survive a JSON round trip bit-for-bit
        let text = r.to_json().to_string();
        let back = ResidencyStats::from_json(&Json::parse(&text).map_err(|e| e.to_string())?)
            .map_err(|e| e.to_string())?;
        prop::assert_prop(back == r, format!("round trip {back:?} != {r:?}"))
    });
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn prop_graph_insert_never_breaks_invariants_under_concurrency() {
    prop::check("concurrent-invariants", 4, |rng| {
        let n = 64;
        let k = 8 + rng.below(24);
        let width = 1 + rng.below(k);
        let mut g = KnnGraph::empty(n, k);
        let jobs: Vec<Vec<(usize, u32, f32)>> = (0..4)
            .map(|_| {
                (0..800)
                    .map(|_| (rng.below(n), rng.below(n) as u32, rng.f32() * 100.0))
                    .collect()
            })
            .collect();
        {
            let cg = gnnd::graph::concurrent::ConcurrentGraph::new(&mut g, width);
            crossbeam_utils::thread::scope(|s| {
                for job in &jobs {
                    let cg = &cg;
                    s.spawn(move |_| {
                        for &(u, v, d) in job {
                            if u != v as usize {
                                cg.insert(u, v, d);
                            }
                        }
                    });
                }
            })
            .unwrap();
        }
        g.normalize_all(2);
        g.check_invariants().map_err(|e| e.to_string())
    });
}
