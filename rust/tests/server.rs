//! Integration: the `gnnd serve` TCP front end — protocol-level
//! request/response over a real loopback socket, typed rejection of
//! malformed frames (the server must never panic on client bytes),
//! coalescing-window parity against the sequential sharded path, and
//! deterministic admission control with exact shed reconciliation.
//!
//! Tests that create servers all serialize on [`GATE`]: the telemetry
//! registry is process-global, and the admission test asserts *exact*
//! `server.accepted` / `server.shed_total` / `client.shed_total`
//! deltas — a server running in a parallel test would skew them.

use std::net::{SocketAddr, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Barrier, Mutex, PoisonError};

use gnnd::config::Metric;
use gnnd::dataset::{synth, Dataset};
use gnnd::gnnd::{GnndParams, NativeEngine};
use gnnd::graph::EMPTY;
use gnnd::merge::outofcore::{
    build_out_of_core, quantize_store, OutOfCoreConfig, ResidencyMode, ShardStore,
};
use gnnd::search::proto::{self, Request, Response, SearchRequest, Status};
use gnnd::search::server::{RemoteIndex, Server, ServerConfig, ServerHandle};
use gnnd::search::sharded::ShardedIndex;
use gnnd::search::{AnnIndex, SearchParams, SearchScratch};
use gnnd::telemetry;
use gnnd::util::json::Json;

static GATE: Mutex<()> = Mutex::new(());

fn gate() -> std::sync::MutexGuard<'static, ()> {
    GATE.lock().unwrap_or_else(PoisonError::into_inner)
}

fn tmpdir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!(
        "gnnd-server-{tag}-{}-{:x}",
        std::process::id(),
        std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .unwrap()
            .as_nanos()
    ));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// A trait-only exact-scan index (the same shape as serve.rs's test
/// double): cheap to build, exactly verifiable, and a layout the
/// server module never heard of.
struct FlatIndex {
    ds: Dataset,
}

impl AnnIndex for FlatIndex {
    fn len(&self) -> usize {
        self.ds.len()
    }

    fn dim(&self) -> usize {
        self.ds.d
    }

    fn metric(&self) -> Metric {
        self.ds.metric
    }

    fn vector(&self, id: u32) -> Vec<f32> {
        self.ds.vec(id as usize).to_vec()
    }

    fn default_ef(&self) -> usize {
        32
    }

    fn describe(&self) -> String {
        format!("flat-exact({} x {})", self.ds.len(), self.ds.d)
    }

    fn make_scratch(&self) -> SearchScratch {
        SearchScratch::new()
    }

    fn search_ef_into_excluding(
        &self,
        q: &[f32],
        k: usize,
        _ef: usize,
        exclude: u32,
        _scratch: &mut SearchScratch,
        out: &mut Vec<(f32, u32)>,
    ) {
        let mut all: Vec<(f32, u32)> = (0..self.ds.len() as u32)
            .filter(|&i| i != exclude)
            .map(|i| (self.ds.dist_to(i as usize, q), i))
            .collect();
        all.sort_by(|a, b| a.partial_cmp(b).unwrap());
        out.clear();
        out.extend(all.into_iter().take(k));
    }
}

/// Run `f` against a live loopback server over `index`. The shutdown
/// guard fires even when `f` panics, so a failing assertion fails the
/// test instead of hanging the accept loop forever.
fn with_server<F: FnOnce(SocketAddr)>(index: &dyn AnnIndex, cfg: ServerConfig, f: F) {
    struct Guard(ServerHandle);
    impl Drop for Guard {
        fn drop(&mut self) {
            self.0.shutdown();
        }
    }
    let srv = Server::bind("127.0.0.1:0", cfg).unwrap();
    let addr = srv.local_addr().unwrap();
    let handle = srv.handle().unwrap();
    crossbeam_utils::thread::scope(|s| {
        let srv = &srv;
        s.builder()
            .name("test-server".to_string())
            .spawn(move |_| srv.run(index).unwrap())
            .unwrap();
        let _guard = Guard(handle);
        f(addr);
    })
    .unwrap();
}

fn exact(flat: &FlatIndex, q: usize, k: usize, exclude: u32) -> Vec<(f32, u32)> {
    let mut out = Vec::new();
    flat.search_ef_into_excluding(
        flat.ds.vec(q),
        k,
        0,
        exclude,
        &mut flat.make_scratch(),
        &mut out,
    );
    out
}

#[test]
fn info_and_multi_query_search_over_loopback() {
    let _gate = gate();
    let flat = FlatIndex { ds: synth::uniform(150, 5, 60) };
    with_server(&flat, ServerConfig::default(), |addr| {
        let mut s = TcpStream::connect(addr).unwrap();
        proto::write_frame(&mut s, &proto::encode_request(&Request::Info)).unwrap();
        let payload = proto::read_frame(&mut s).unwrap().expect("info response frame");
        let info = match proto::decode_response(&payload).unwrap() {
            Response::Info(i) => i,
            other => panic!("expected info response, got {other:?}"),
        };
        assert_eq!(info.n, 150);
        assert_eq!(info.d, 5);
        assert_eq!(info.default_ef, 32);
        assert_eq!(info.metric, flat.ds.metric.to_string());
        assert!(info.describe.contains("flat-exact"), "describe: {}", info.describe);

        // a multi-query frame (RemoteIndex never sends one) rides a
        // single coalesced pass; row 1 excludes itself
        let rows = [3usize, 77, 149];
        let mut queries = Vec::new();
        for &q in &rows {
            queries.extend_from_slice(flat.ds.vec(q));
        }
        let req = Request::Search(SearchRequest {
            k: 4,
            ef: 0,
            rerank: 0,
            d: 5,
            queries,
            exclude: vec![u32::MAX, 77, u32::MAX],
        });
        proto::write_frame(&mut s, &proto::encode_request(&req)).unwrap();
        let payload = proto::read_frame(&mut s).unwrap().expect("search response frame");
        let resp = match proto::decode_response(&payload).unwrap() {
            Response::Search(r) => r,
            other => panic!("expected search response, got {other:?}"),
        };
        assert_eq!(resp.k, 4);
        assert_eq!(resp.results.len(), 3);
        for (i, &q) in rows.iter().enumerate() {
            let exclude = if i == 1 { 77 } else { EMPTY };
            assert_eq!(
                resp.results[i],
                exact(&flat, q, 4, exclude),
                "server answer diverged from exact scan on row {i}"
            );
        }

        // well-formed but inconsistent: typed BadRequest, and the
        // connection survives to serve the next request
        let bad = Request::Search(SearchRequest {
            k: 2,
            ef: 0,
            rerank: 0,
            d: 4,
            queries: vec![0.0; 4],
            exclude: vec![u32::MAX],
        });
        proto::write_frame(&mut s, &proto::encode_request(&bad)).unwrap();
        let payload = proto::read_frame(&mut s).unwrap().expect("error response frame");
        match proto::decode_response(&payload).unwrap() {
            Response::Error(e) => {
                assert_eq!(e.status, Status::BadRequest);
                assert!(e.msg.contains("dimension"), "unhelpful error: {}", e.msg);
            }
            other => panic!("expected error response, got {other:?}"),
        }
        proto::write_frame(&mut s, &proto::encode_request(&Request::Info)).unwrap();
        assert!(
            proto::read_frame(&mut s).unwrap().is_some(),
            "dimension mismatch must not kill the connection"
        );
    });
}

#[test]
fn malformed_frames_get_typed_errors_and_server_survives() {
    let _gate = gate();
    let flat = FlatIndex { ds: synth::uniform(80, 4, 61) };
    with_server(&flat, ServerConfig::default(), |addr| {
        // every case gets a fresh connection (the server closes after a
        // protocol violation) and must read back a typed BadRequest —
        // never a hang, never a server panic
        let expect_bad = |bytes: &[u8], half_close: bool, tag: &str| {
            let mut s = TcpStream::connect(addr).unwrap();
            {
                use std::io::Write;
                s.write_all(bytes).unwrap();
                s.flush().unwrap();
            }
            if half_close {
                s.shutdown(std::net::Shutdown::Write).unwrap();
            }
            let payload = proto::read_frame(&mut s)
                .unwrap()
                .unwrap_or_else(|| panic!("{tag}: server closed without a typed error"));
            match proto::decode_response(&payload).unwrap() {
                Response::Error(e) => {
                    assert_eq!(e.status, Status::BadRequest, "{tag}: wrong status: {}", e.msg)
                }
                other => panic!("{tag}: expected error response, got {other:?}"),
            }
        };

        // oversized length prefix: rejected before any allocation
        expect_bad(
            &((proto::MAX_FRAME_BYTES + 1) as u32).to_le_bytes(),
            false,
            "oversized",
        );
        // length below the mandatory 8-byte payload header
        expect_bad(&4u32.to_le_bytes(), false, "sub-header length");
        // frame cut mid-payload, then EOF
        let good = proto::encode_request(&Request::Search(SearchRequest {
            k: 3,
            ef: 0,
            rerank: 0,
            d: 4,
            queries: vec![0.5; 8],
            exclude: vec![u32::MAX, u32::MAX],
        }));
        expect_bad(&good[..good.len() / 2], true, "truncated");
        // bad magic / bad version / unknown kind, each in a full frame
        let mut bad_magic = good.clone();
        bad_magic[4] ^= 0xFF;
        expect_bad(&bad_magic, false, "bad magic");
        let mut bad_version = good.clone();
        bad_version[8] = 0x7F;
        expect_bad(&bad_version, false, "bad version");
        let mut bad_kind = good.clone();
        bad_kind[10] = 0x77;
        expect_bad(&bad_kind, false, "unknown kind");
        // nq inflated past the bytes actually present (lying counts)
        let mut inflated = good.clone();
        let nq_off = 4 + proto::HEADER_BYTES + 12 + 4; // prefix+header+k/ef/rerank+d
        inflated[nq_off] = 200;
        expect_bad(&inflated, false, "nq inflation");

        // after all that abuse a fresh connection still serves
        let mut s = TcpStream::connect(addr).unwrap();
        proto::write_frame(&mut s, &proto::encode_request(&Request::Info)).unwrap();
        let payload = proto::read_frame(&mut s).unwrap().expect("server died on garbage");
        assert!(matches!(
            proto::decode_response(&payload).unwrap(),
            Response::Info(_)
        ));
    });
}

/// The tentpole acceptance grid: server answers are **bit-identical**
/// to the sequential in-process `ShardedIndex` at every coalescing
/// window — across probe caps, executor thread counts, and the
/// quantized-with-rerank backing — while concurrent client connections
/// force real coalescing. Extends the pool-parity grid of
/// `tests/sharded.rs` one layer up, through the socket.
#[test]
fn coalescing_parity_grid_matches_sequential_sharded() {
    let _gate = gate();
    let ds = synth::clustered(480, 8, 62);
    let params = GnndParams::default().with_k(10).with_p(5).with_iters(6);
    let cfg = OutOfCoreConfig { shards: 4, workers: 2, params };
    let dir = tmpdir("paritygrid");
    build_out_of_core(&ds, &dir, &cfg, &NativeEngine).unwrap();
    quantize_store(&dir).unwrap();

    let qids: Vec<usize> = (0..ds.len()).step_by(37).collect();
    for (quantize, rerank) in [(false, 1usize), (true, 4)] {
        for probe in [0usize, 2] {
            let sp = SearchParams::default().with_ef(48).with_rerank(rerank);
            let store =
                ShardStore::with_options(&dir, 0, ResidencyMode::Shard, quantize).unwrap();
            let index = ShardedIndex::from_store(store, sp, probe, 1).unwrap();
            // sequential in-process expectations
            let mut scratch = index.make_scratch();
            let mut out = Vec::new();
            let expected: Vec<Vec<(f32, u32)>> = qids
                .iter()
                .map(|&q| {
                    index.search_ef_into_excluding(
                        ds.vec(q),
                        10,
                        0,
                        q as u32,
                        &mut scratch,
                        &mut out,
                    );
                    out.clone()
                })
                .collect();
            for window_us in [0u64, 100, 5000] {
                for exec_threads in [1usize, 4] {
                    let scfg = ServerConfig {
                        coalesce_window_us: window_us,
                        queue_limit: 4096,
                        exec_threads,
                        debug_slow_shard_ms: 0,
                        stats_out: None,
                    };
                    with_server(&index, scfg, |addr| {
                        let remote = RemoteIndex::connect(&addr.to_string()).unwrap();
                        let mut got: Vec<Vec<(f32, u32)>> = vec![Vec::new(); qids.len()];
                        crossbeam_utils::thread::scope(|s| {
                            let handles: Vec<_> = (0..3)
                                .map(|chunk| {
                                    let remote = &remote;
                                    let qids = &qids;
                                    let ds = &ds;
                                    s.spawn(move |_| {
                                        let mut scratch = remote.make_scratch();
                                        let mut out = Vec::new();
                                        let mut mine = Vec::new();
                                        for (i, &q) in qids.iter().enumerate() {
                                            if i % 3 != chunk {
                                                continue;
                                            }
                                            remote.search_ef_into_excluding(
                                                ds.vec(q),
                                                10,
                                                0,
                                                q as u32,
                                                &mut scratch,
                                                &mut out,
                                            );
                                            assert_eq!(
                                                scratch.dist_evals, 0,
                                                "remote work counters must read 0"
                                            );
                                            mine.push((i, out.clone()));
                                        }
                                        mine
                                    })
                                })
                                .collect();
                            for h in handles {
                                for (i, r) in h.join().unwrap() {
                                    got[i] = r;
                                }
                            }
                        })
                        .unwrap();
                        for (i, exp) in expected.iter().enumerate() {
                            assert_eq!(
                                &got[i], exp,
                                "server diverged from sequential (quantize={quantize} \
                                 rerank={rerank} probe={probe} window={window_us}µs \
                                 exec_threads={exec_threads}) on query {}",
                                qids[i]
                            );
                        }
                    });
                }
            }
        }
    }
    std::fs::remove_dir_all(dir).ok();
}

/// Admission control under a deterministically slow batcher
/// (`debug_slow_shard_ms`): shed requests answer `Overloaded` (surfacing
/// as empty result lists through [`RemoteIndex`]), accepted requests
/// answer exactly, and the server-side `shed_total` reconciles **exactly**
/// with the sheds the clients observed.
#[test]
fn admission_control_sheds_with_exact_reconciliation() {
    let _gate = gate();
    let flat = FlatIndex { ds: synth::uniform(200, 6, 63) };
    let scfg = ServerConfig {
        coalesce_window_us: 0,
        queue_limit: 1,
        exec_threads: 1,
        debug_slow_shard_ms: 100,
        stats_out: None,
    };
    let g = telemetry::global();
    let acc0 = g.counter("server.accepted").get();
    let shed0 = g.counter("server.shed_total").get();
    let cshed0 = g.counter("client.shed_total").get();

    const CLIENTS: usize = 6;
    const PER_CLIENT: usize = 3;
    let observed_shed = AtomicUsize::new(0);
    let observed_ok = AtomicUsize::new(0);
    with_server(&flat, scfg, |addr| {
        let remote = RemoteIndex::connect(&addr.to_string()).unwrap();
        let barrier = Barrier::new(CLIENTS);
        crossbeam_utils::thread::scope(|s| {
            for t in 0..CLIENTS {
                let remote = &remote;
                let barrier = &barrier;
                let flat = &flat;
                let observed_shed = &observed_shed;
                let observed_ok = &observed_ok;
                s.spawn(move |_| {
                    let mut scratch = remote.make_scratch();
                    let mut out = Vec::new();
                    barrier.wait();
                    for i in 0..PER_CLIENT {
                        let q = (t * 17 + i * 5) % flat.ds.len();
                        remote.search_ef_into_excluding(
                            flat.ds.vec(q),
                            5,
                            0,
                            EMPTY,
                            &mut scratch,
                            &mut out,
                        );
                        if out.is_empty() {
                            observed_shed.fetch_add(1, Ordering::Relaxed);
                        } else {
                            assert_eq!(
                                out,
                                exact(flat, q, 5, EMPTY),
                                "accepted query {q} answered wrong under load"
                            );
                            observed_ok.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                });
            }
        })
        .unwrap();
    });

    let shed = observed_shed.load(Ordering::Relaxed) as u64;
    let ok = observed_ok.load(Ordering::Relaxed) as u64;
    assert_eq!(shed + ok, (CLIENTS * PER_CLIENT) as u64, "every request must resolve");
    assert!(shed > 0, "queue_limit=1 under {CLIENTS} concurrent clients must shed");
    assert!(ok > 0, "the first push into an empty queue is always admitted");
    assert_eq!(
        g.counter("server.shed_total").get() - shed0,
        shed,
        "server sheds must reconcile exactly with client-observed sheds"
    );
    assert_eq!(
        g.counter("client.shed_total").get() - cshed0,
        shed,
        "RemoteIndex must count exactly the Overloaded responses it saw"
    );
    assert_eq!(
        g.counter("server.accepted").get() - acc0,
        ok,
        "accepted count must match successfully answered requests"
    );
}

/// `--stats-out`: the server keeps an atomically-rewritten telemetry
/// snapshot on disk; after shutdown it parses and carries the server
/// metrics (this is what CI reads after killing the serve process).
#[test]
fn stats_out_snapshot_parses_and_carries_server_metrics() {
    let _gate = gate();
    let flat = FlatIndex { ds: synth::uniform(100, 4, 64) };
    let dir = tmpdir("stats");
    let path = dir.join("server_stats.json");
    let scfg = ServerConfig {
        stats_out: Some(path.to_string_lossy().into_owned()),
        ..Default::default()
    };
    with_server(&flat, scfg, |addr| {
        let remote = RemoteIndex::connect(&addr.to_string()).unwrap();
        let mut scratch = remote.make_scratch();
        let mut out = Vec::new();
        remote.search_ef_into_excluding(flat.ds.vec(0), 5, 0, EMPTY, &mut scratch, &mut out);
        assert_eq!(out.len(), 5);
    });
    let text = std::fs::read_to_string(&path).unwrap();
    Json::parse(&text).unwrap();
    for key in ["server.accepted", "server.connections", "server.coalesced_batch_size"] {
        assert!(text.contains(key), "stats snapshot missing {key}");
    }
    std::fs::remove_dir_all(dir).ok();
}
