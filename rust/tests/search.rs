//! Integration: the search/serving subsystem over graphs produced by
//! the real construction pipeline (GNND), per the subsystem contract:
//! search beats the raw graph's neighbor lists, batching is
//! bit-identical to single-query execution, and a fixed seed gives
//! deterministic output.

use std::collections::HashSet;

use gnnd::baselines::bruteforce;
use gnnd::dataset::{groundtruth, synth, Dataset};
use gnnd::graph::KnnGraph;
use gnnd::gnnd::{build, GnndParams};
use gnnd::metrics::recall_at;
use gnnd::search::{batch::BatchExecutor, serve, EntryStrategy, SearchIndex, SearchParams};

fn recall_of_search(
    ds: &Dataset,
    index: &SearchIndex,
    qids: &[usize],
    truth: &[Vec<u32>],
    k: usize,
) -> f64 {
    let mut scratch = index.make_scratch();
    let mut out = Vec::new();
    let mut hit = 0usize;
    let mut total = 0usize;
    for (row, &q) in truth.iter().zip(qids) {
        index.search_into_excluding(ds.vec(q), k, q as u32, &mut scratch, &mut out);
        let set: HashSet<u32> = out.iter().map(|&(_, id)| id).collect();
        hit += row.iter().take(k).filter(|id| set.contains(id)).count();
        total += row.len().min(k);
    }
    hit as f64 / total as f64
}

#[test]
fn search_beats_raw_graph_lists_on_sift_like() {
    // A deliberately under-converged GNND graph: its raw top-10 lists
    // miss true neighbors, but beam search walks the graph and recovers
    // them — the premise of serving from the construction output.
    let ds = synth::sift_like(2_000, 0x5EA1);
    let params = GnndParams::default().with_k(10).with_p(5).with_iters(2);
    let g = build(&ds, &params).unwrap();
    let (qids, truth) = groundtruth::sampled_truth(&ds, 200, 10, 3);
    let raw = recall_at(&g, &truth, Some(&qids), 10);

    let sp = SearchParams::default().with_ef(128).with_entries(EntryStrategy::Random, 16);
    let index = SearchIndex::new(&ds, &g, sp).unwrap();
    let searched = recall_of_search(&ds, &index, &qids, &truth, 10);

    assert!(
        searched > raw,
        "search recall {searched} does not beat raw graph lists {raw}"
    );
    assert!(searched > 0.8, "search recall {searched} too low (raw {raw})");
}

#[test]
fn serve_sweep_reaches_high_recall_on_converged_graph() {
    // The serve-bench acceptance shape at test scale: a converged graph
    // must reach recall@10 >= 0.95 at some ef operating point.
    let ds = synth::sift_like(1_500, 0x5EA2);
    let params = GnndParams::default().with_k(16).with_p(8).with_iters(8);
    let g = build(&ds, &params).unwrap();
    let cfg = serve::ServeConfig {
        ef_sweep: vec![8, 32, 128],
        n_queries: 200,
        distinct_queries: 150,
        threads: 2,
        ..Default::default()
    };
    let index = SearchIndex::new(&ds, &g, cfg.params.clone()).unwrap();
    let report = serve::run_sweep_on(&index, &ds, &cfg).unwrap();
    assert_eq!(report.rows.len(), 3);
    for row in &report.rows {
        let get = |name: &str| row.cols.iter().find(|(n, _)| n == name).unwrap().1;
        assert!(get("qps") > 0.0);
        assert!(get("p99_ms") >= get("p50_ms"));
        assert!((0.0..=1.0).contains(&get("recall@10")));
    }
    let best = report
        .rows
        .iter()
        .filter_map(|r| r.cols.iter().find(|(n, _)| n == "recall@10").map(|&(_, v)| v))
        .fold(0.0f64, f64::max);
    assert!(best >= 0.95, "no ef operating point reached recall 0.95 (best {best})");
}

#[test]
fn batched_results_are_bit_identical_to_single_query() {
    let ds = synth::sift_like(1_000, 0x5EA3);
    let params = GnndParams::default().with_k(12).with_p(6).with_iters(5);
    let g = build(&ds, &params).unwrap();
    let index = SearchIndex::new(&ds, &g, SearchParams::default()).unwrap();

    let nq = 64;
    let mut qbuf = Vec::with_capacity(nq * ds.d);
    let mut exclude = Vec::with_capacity(nq);
    for q in 0..nq {
        qbuf.extend_from_slice(ds.vec(q * 7 % ds.len()));
        exclude.push((q * 7 % ds.len()) as u32);
    }
    for threads in [1usize, 4] {
        let batched =
            BatchExecutor::new(&index, threads).run_excluding(&qbuf, ds.d, 10, &exclude);
        let mut scratch = index.make_scratch();
        let mut single = Vec::new();
        for (qi, want) in batched.iter().enumerate() {
            index.search_into_excluding(
                &qbuf[qi * ds.d..(qi + 1) * ds.d],
                10,
                exclude[qi],
                &mut scratch,
                &mut single,
            );
            assert_eq!(
                want, &single,
                "batched (threads={threads}) differs from single for query {qi}"
            );
        }
    }
}

#[test]
fn batch_thread_count_does_not_change_results() {
    let ds = synth::clustered(250, 6, 102);
    let g = bruteforce::build_native(&ds, 8);
    let index = SearchIndex::new(&ds, &g, SearchParams::default()).unwrap();
    let nq = 30;
    let mut qbuf = Vec::new();
    for q in 0..nq {
        qbuf.extend_from_slice(ds.vec(q));
    }
    let a = BatchExecutor::new(&index, 1).run(&qbuf, ds.d, 5);
    let b = BatchExecutor::new(&index, 3).run(&qbuf, ds.d, 5);
    assert_eq!(a, b);
}

#[test]
fn batch_ef_override_matches_reconfigured_index() {
    // BatchExecutor::with_ef(ef) must behave exactly like an index
    // whose params carry that ef — the serve harness relies on it.
    let ds = synth::clustered(300, 6, 104);
    let g = bruteforce::build_native(&ds, 8);
    let base = SearchIndex::new(&ds, &g, SearchParams::default().with_ef(16)).unwrap();
    let nq = 25;
    let mut qbuf = Vec::new();
    for q in 0..nq {
        qbuf.extend_from_slice(ds.vec(q));
    }
    let overridden = BatchExecutor::new(&base, 2).with_ef(96).run(&qbuf, ds.d, 10);
    let reconfigured = base.with_ef(96);
    let direct = BatchExecutor::new(&reconfigured, 2).run(&qbuf, ds.d, 10);
    assert_eq!(overridden, direct);
}

#[test]
fn empty_batch_is_fine() {
    let ds = synth::uniform(60, 4, 103);
    let g = bruteforce::build_native(&ds, 6);
    let index = SearchIndex::new(&ds, &g, SearchParams::default()).unwrap();
    let out = BatchExecutor::new(&index, 2).run(&[], ds.d, 5);
    assert!(out.is_empty());
}

#[test]
fn fixed_seed_gives_deterministic_output() {
    let ds = synth::sift_like(800, 0x5EA4);
    let params = GnndParams::default().with_k(10).with_p(5).with_iters(4);
    let g = build(&ds, &params).unwrap();
    for strategy in [EntryStrategy::Random, EntryStrategy::KMeans] {
        let sp = SearchParams::default().with_entries(strategy, 8).with_seed(0xD5);
        let a = SearchIndex::new(&ds, &g, sp.clone()).unwrap();
        let b = SearchIndex::new(&ds, &g, sp).unwrap();
        assert_eq!(a.entries(), b.entries());
        for q in (0..ds.len()).step_by(97) {
            assert_eq!(
                a.search(ds.vec(q), 10),
                b.search(ds.vec(q), 10),
                "nondeterministic results for {q} under {strategy}"
            );
        }
    }
}

#[test]
fn arrival_schedules_are_seeded_and_reproducible() {
    // the same (n, rate, process, seed) replays the exact same schedule
    let a = serve::arrival_schedule(500, 200.0, serve::Arrival::Poisson, 7);
    let b = serve::arrival_schedule(500, 200.0, serve::Arrival::Poisson, 7);
    assert_eq!(a, b, "seeded Poisson schedule must be reproducible across runs");
    let c = serve::arrival_schedule(500, 200.0, serve::Arrival::Poisson, 8);
    assert_ne!(a, c, "distinct seeds must give distinct schedules");

    // arrivals start at t=0, never go backwards, and pace ~n/rate
    assert_eq!(a[0], 0.0);
    assert!(a.windows(2).all(|w| w[1] >= w[0]), "arrival times must be non-decreasing");
    let span = *a.last().unwrap();
    let expect = 499.0 / 200.0;
    assert!(
        (0.7..1.3).contains(&(span / expect)),
        "Poisson span {span:.3}s far from expected {expect:.3}s"
    );

    // the fixed-interval process is exactly 1/rate apart
    let u = serve::arrival_schedule(10, 100.0, serve::Arrival::Uniform, 7);
    for (i, t) in u.iter().enumerate() {
        assert!((t - i as f64 * 0.01).abs() < 1e-12, "uniform arrival {i} at {t}");
    }
}

#[test]
fn open_loop_recall_matches_closed_loop_and_overload_flag_trips() {
    let ds = synth::clustered(300, 6, 0x5EA7);
    let g = bruteforce::build_native(&ds, 8);
    let index = SearchIndex::new(&ds, &g, SearchParams::default()).unwrap();
    let stream = serve::sample_queries(&ds, 60, 10, 5);
    let base = serve::ServeConfig {
        n_queries: 120,
        distinct_queries: 60,
        threads: 2,
        ..Default::default()
    };
    let closed = serve::run_point(&index, &stream, &base, 32);
    assert!(!closed.overload, "closed loop can never be overloaded");

    // a saturating arrival rate: every query is due immediately, so the
    // open loop issues the same queries in the same order as the closed
    // loop — recall (a property of the queries, not their arrival
    // times) must match exactly, and a tiny index cannot possibly keep
    // up with 1e9 offered qps, so the overload flag must trip
    let open_cfg = serve::ServeConfig { arrival_rate: 1e9, ..base.clone() };
    let open = serve::run_point(&index, &stream, &open_cfg, 32);
    assert_eq!(
        open.recall, closed.recall,
        "open-loop recall diverged from closed-loop on the same queries"
    );
    assert!(open.queue_p99_ms >= open.queue_p50_ms, "queue tail below median");
    assert!(open.overload, "offered 1e9 qps must overload (achieved {:.0})", open.qps);
    assert!(open.qps < 1e9 * 0.95);

    // a comfortably low offered rate is achieved (no overload) and the
    // queue stays near-empty — fixed-interval arrivals so the only
    // queueing left is service-time jitter. Sizing the slack: 400
    // queries at 200 qps span ~2.0 s of absolute deadlines, and the
    // overload margin (0.95) only trips if the whole pass takes over
    // 400/190 ≈ 2.1 s — arrival deadlines are absolute, so per-sleep
    // overshoot does not accumulate and only a >100 ms stall at the
    // very end of the run could flake this
    let low_cfg = serve::ServeConfig {
        n_queries: 400,
        arrival_rate: 200.0,
        arrival: serve::Arrival::Uniform,
        ..base
    };
    let low = serve::run_point(&index, &stream, &low_cfg, 32);
    assert!(
        !low.overload,
        "200 qps offered must not overload a flat 300-point index (achieved {:.0})",
        low.qps
    );
    assert!(low.queue_p99_ms >= low.queue_p50_ms);
}

#[test]
fn serving_works_over_a_loaded_graph_file() {
    // Round-trip through the on-disk format: any persisted build output
    // (in-core, merged, out-of-core) must serve identically.
    let ds = synth::clustered(600, 8, 0x5EA5);
    let params = GnndParams::default().with_k(10).with_p(5).with_iters(6);
    let g = build(&ds, &params).unwrap();
    let dir = std::env::temp_dir().join(format!("gnnd-search-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("g.knng");
    g.save(&path).unwrap();
    let loaded = KnnGraph::load(&path).unwrap();

    let sp = SearchParams::default().with_ef(64);
    let a = SearchIndex::new(&ds, &g, sp.clone()).unwrap();
    let b = SearchIndex::new(&ds, &loaded, sp).unwrap();
    for q in (0..ds.len()).step_by(53) {
        assert_eq!(a.search(ds.vec(q), 10), b.search(ds.vec(q), 10), "q={q}");
    }
    std::fs::remove_dir_all(dir).ok();
}
