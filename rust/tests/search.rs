//! Integration: the search/serving subsystem over graphs produced by
//! the real construction pipeline (GNND), per the subsystem contract:
//! search beats the raw graph's neighbor lists, batching is
//! bit-identical to single-query execution, and a fixed seed gives
//! deterministic output.

use std::collections::HashSet;

use gnnd::baselines::bruteforce;
use gnnd::dataset::{groundtruth, synth, Dataset};
use gnnd::graph::KnnGraph;
use gnnd::gnnd::{build, GnndParams};
use gnnd::metrics::recall_at;
use gnnd::search::{batch::BatchExecutor, serve, EntryStrategy, SearchIndex, SearchParams};

fn recall_of_search(
    ds: &Dataset,
    index: &SearchIndex,
    qids: &[usize],
    truth: &[Vec<u32>],
    k: usize,
) -> f64 {
    let mut scratch = index.make_scratch();
    let mut out = Vec::new();
    let mut hit = 0usize;
    let mut total = 0usize;
    for (row, &q) in truth.iter().zip(qids) {
        index.search_into_excluding(ds.vec(q), k, q as u32, &mut scratch, &mut out);
        let set: HashSet<u32> = out.iter().map(|&(_, id)| id).collect();
        hit += row.iter().take(k).filter(|id| set.contains(id)).count();
        total += row.len().min(k);
    }
    hit as f64 / total as f64
}

#[test]
fn search_beats_raw_graph_lists_on_sift_like() {
    // A deliberately under-converged GNND graph: its raw top-10 lists
    // miss true neighbors, but beam search walks the graph and recovers
    // them — the premise of serving from the construction output.
    let ds = synth::sift_like(2_000, 0x5EA1);
    let params = GnndParams::default().with_k(10).with_p(5).with_iters(2);
    let g = build(&ds, &params).unwrap();
    let (qids, truth) = groundtruth::sampled_truth(&ds, 200, 10, 3);
    let raw = recall_at(&g, &truth, Some(&qids), 10);

    let sp = SearchParams::default().with_ef(128).with_entries(EntryStrategy::Random, 16);
    let index = SearchIndex::new(&ds, &g, sp).unwrap();
    let searched = recall_of_search(&ds, &index, &qids, &truth, 10);

    assert!(
        searched > raw,
        "search recall {searched} does not beat raw graph lists {raw}"
    );
    assert!(searched > 0.8, "search recall {searched} too low (raw {raw})");
}

#[test]
fn serve_sweep_reaches_high_recall_on_converged_graph() {
    // The serve-bench acceptance shape at test scale: a converged graph
    // must reach recall@10 >= 0.95 at some ef operating point.
    let ds = synth::sift_like(1_500, 0x5EA2);
    let params = GnndParams::default().with_k(16).with_p(8).with_iters(8);
    let g = build(&ds, &params).unwrap();
    let cfg = serve::ServeConfig {
        ef_sweep: vec![8, 32, 128],
        n_queries: 200,
        distinct_queries: 150,
        threads: 2,
        ..Default::default()
    };
    let index = SearchIndex::new(&ds, &g, cfg.params.clone()).unwrap();
    let report = serve::run_sweep_on(&index, &ds, &cfg).unwrap();
    assert_eq!(report.rows.len(), 3);
    for row in &report.rows {
        let get = |name: &str| row.cols.iter().find(|(n, _)| n == name).unwrap().1;
        assert!(get("qps") > 0.0);
        assert!(get("p99_ms") >= get("p50_ms"));
        assert!((0.0..=1.0).contains(&get("recall@10")));
    }
    let best = report
        .rows
        .iter()
        .filter_map(|r| r.cols.iter().find(|(n, _)| n == "recall@10").map(|&(_, v)| v))
        .fold(0.0f64, f64::max);
    assert!(best >= 0.95, "no ef operating point reached recall 0.95 (best {best})");
}

#[test]
fn batched_results_are_bit_identical_to_single_query() {
    let ds = synth::sift_like(1_000, 0x5EA3);
    let params = GnndParams::default().with_k(12).with_p(6).with_iters(5);
    let g = build(&ds, &params).unwrap();
    let index = SearchIndex::new(&ds, &g, SearchParams::default()).unwrap();

    let nq = 64;
    let mut qbuf = Vec::with_capacity(nq * ds.d);
    let mut exclude = Vec::with_capacity(nq);
    for q in 0..nq {
        qbuf.extend_from_slice(ds.vec(q * 7 % ds.len()));
        exclude.push((q * 7 % ds.len()) as u32);
    }
    for threads in [1usize, 4] {
        let batched =
            BatchExecutor::new(&index, threads).run_excluding(&qbuf, ds.d, 10, &exclude);
        let mut scratch = index.make_scratch();
        let mut single = Vec::new();
        for (qi, want) in batched.iter().enumerate() {
            index.search_into_excluding(
                &qbuf[qi * ds.d..(qi + 1) * ds.d],
                10,
                exclude[qi],
                &mut scratch,
                &mut single,
            );
            assert_eq!(
                want, &single,
                "batched (threads={threads}) differs from single for query {qi}"
            );
        }
    }
}

#[test]
fn batch_thread_count_does_not_change_results() {
    let ds = synth::clustered(250, 6, 102);
    let g = bruteforce::build_native(&ds, 8);
    let index = SearchIndex::new(&ds, &g, SearchParams::default()).unwrap();
    let nq = 30;
    let mut qbuf = Vec::new();
    for q in 0..nq {
        qbuf.extend_from_slice(ds.vec(q));
    }
    let a = BatchExecutor::new(&index, 1).run(&qbuf, ds.d, 5);
    let b = BatchExecutor::new(&index, 3).run(&qbuf, ds.d, 5);
    assert_eq!(a, b);
}

#[test]
fn batch_ef_override_matches_reconfigured_index() {
    // BatchExecutor::with_ef(ef) must behave exactly like an index
    // whose params carry that ef — the serve harness relies on it.
    let ds = synth::clustered(300, 6, 104);
    let g = bruteforce::build_native(&ds, 8);
    let base = SearchIndex::new(&ds, &g, SearchParams::default().with_ef(16)).unwrap();
    let nq = 25;
    let mut qbuf = Vec::new();
    for q in 0..nq {
        qbuf.extend_from_slice(ds.vec(q));
    }
    let overridden = BatchExecutor::new(&base, 2).with_ef(96).run(&qbuf, ds.d, 10);
    let reconfigured = base.with_ef(96);
    let direct = BatchExecutor::new(&reconfigured, 2).run(&qbuf, ds.d, 10);
    assert_eq!(overridden, direct);
}

#[test]
fn empty_batch_is_fine() {
    let ds = synth::uniform(60, 4, 103);
    let g = bruteforce::build_native(&ds, 6);
    let index = SearchIndex::new(&ds, &g, SearchParams::default()).unwrap();
    let out = BatchExecutor::new(&index, 2).run(&[], ds.d, 5);
    assert!(out.is_empty());
}

#[test]
fn fixed_seed_gives_deterministic_output() {
    let ds = synth::sift_like(800, 0x5EA4);
    let params = GnndParams::default().with_k(10).with_p(5).with_iters(4);
    let g = build(&ds, &params).unwrap();
    for strategy in [EntryStrategy::Random, EntryStrategy::KMeans] {
        let sp = SearchParams::default().with_entries(strategy, 8).with_seed(0xD5);
        let a = SearchIndex::new(&ds, &g, sp.clone()).unwrap();
        let b = SearchIndex::new(&ds, &g, sp).unwrap();
        assert_eq!(a.entries(), b.entries());
        for q in (0..ds.len()).step_by(97) {
            assert_eq!(
                a.search(ds.vec(q), 10),
                b.search(ds.vec(q), 10),
                "nondeterministic results for {q} under {strategy}"
            );
        }
    }
}

#[test]
fn serving_works_over_a_loaded_graph_file() {
    // Round-trip through the on-disk format: any persisted build output
    // (in-core, merged, out-of-core) must serve identically.
    let ds = synth::clustered(600, 8, 0x5EA5);
    let params = GnndParams::default().with_k(10).with_p(5).with_iters(6);
    let g = build(&ds, &params).unwrap();
    let dir = std::env::temp_dir().join(format!("gnnd-search-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("g.knng");
    g.save(&path).unwrap();
    let loaded = KnnGraph::load(&path).unwrap();

    let sp = SearchParams::default().with_ef(64);
    let a = SearchIndex::new(&ds, &g, sp.clone()).unwrap();
    let b = SearchIndex::new(&ds, &loaded, sp).unwrap();
    for q in (0..ds.len()).step_by(53) {
        assert_eq!(a.search(ds.vec(q), 10), b.search(ds.vec(q), 10), "q={q}");
    }
    std::fs::remove_dir_all(dir).ok();
}
