//! Integration: the `gnnd` binary end to end — gen-data -> ground-truth
//! -> build -> eval -> ooc-build, through the real CLI surface.

use std::process::Command;

fn bin() -> &'static str {
    env!("CARGO_BIN_EXE_gnnd")
}

fn run(args: &[&str]) -> (bool, String) {
    let out = Command::new(bin()).args(args).output().expect("spawn gnnd");
    let text = format!(
        "{}{}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
    (out.status.success(), text)
}

fn tmpdir() -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!(
        "gnnd-cli-{}-{:x}",
        std::process::id(),
        std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .unwrap()
            .as_nanos()
    ));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

#[test]
fn full_cli_pipeline() {
    let dir = tmpdir();
    let data = dir.join("d.dsb").to_string_lossy().into_owned();
    let gt = dir.join("gt.ivecs").to_string_lossy().into_owned();
    let graph = dir.join("g.knng").to_string_lossy().into_owned();

    let (ok, out) = run(&["gen-data", "--name", "clustered", "--n", "800", "--out", &data]);
    assert!(ok, "gen-data failed: {out}");

    let (ok, out) = run(&["ground-truth", "--data", &data, "--k", "10", "--out", &gt]);
    assert!(ok, "ground-truth failed: {out}");

    let (ok, out) = run(&[
        "build", "--data", &data, "--out", &graph, "--set", "k=12", "--set", "p=6",
        "--set", "max_iter=6",
    ]);
    assert!(ok, "build failed: {out}");
    assert!(out.contains("built 800"), "unexpected build output: {out}");

    let (ok, out) = run(&["eval", "--data", &data, "--graph", &graph, "--truth", &gt]);
    assert!(ok, "eval failed: {out}");
    let recall: f64 = out
        .split("recall@10 = ")
        .nth(1)
        .and_then(|s| s.split_whitespace().next())
        .and_then(|s| s.parse().ok())
        .unwrap_or_else(|| panic!("cannot parse eval output: {out}"));
    assert!(recall > 0.85, "cli pipeline recall {recall}: {out}");

    // out-of-core through the CLI
    let shard_dir = dir.join("shards").to_string_lossy().into_owned();
    let graph2 = dir.join("g2.knng").to_string_lossy().into_owned();
    let (ok, out) = run(&[
        "ooc-build", "--data", &data, "--dir", &shard_dir, "--shards", "3",
        "--workers", "2", "--out", &graph2, "--set", "k=12", "--set", "p=6",
        "--set", "max_iter=5",
    ]);
    assert!(ok, "ooc-build failed: {out}");
    let (ok, out) = run(&["eval", "--data", &data, "--graph", &graph2, "--truth", &gt]);
    assert!(ok, "eval-2 failed: {out}");
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn cli_search_and_serve_bench() {
    let dir = tmpdir();
    let data = dir.join("d.dsb").to_string_lossy().into_owned();
    let graph = dir.join("g.knng").to_string_lossy().into_owned();
    let (ok, out) = run(&["gen-data", "--name", "clustered", "--n", "500", "--out", &data]);
    assert!(ok, "gen-data failed: {out}");
    let (ok, out) = run(&[
        "build", "--data", &data, "--out", &graph, "--set", "k=10", "--set", "p=5",
        "--set", "max_iter=5",
    ]);
    assert!(ok, "build failed: {out}");

    // single query
    let (ok, out) = run(&[
        "search", "--data", &data, "--graph", &graph, "--query-id", "7", "--k", "5",
        "--ef", "32",
    ]);
    assert!(ok, "search failed: {out}");
    assert!(out.contains("top-5"), "unexpected search output: {out}");

    // batched queries from a .dsb file (reuse the dataset as queries)
    let res = dir.join("res.ivecs").to_string_lossy().into_owned();
    let (ok, out) = run(&[
        "search", "--data", &data, "--graph", &graph, "--queries", &data, "--k", "5",
        "--out", &res,
    ]);
    assert!(ok, "batched search failed: {out}");
    assert!(std::path::Path::new(&res).exists(), "no ivecs written: {out}");

    // serve-bench: one row per ef point, recall column present; the
    // sub-k point (ef=8 < k=10) is clamped to k with a warning
    let (ok, out) = run(&[
        "serve-bench", "--data", &data, "--graph", &graph, "--ef", "8,32,64",
        "--queries", "120", "--distinct", "60", "--threads", "2",
    ]);
    assert!(ok, "serve-bench failed: {out}");
    assert!(out.contains("recall@10"), "no recall column: {out}");
    for ef in ["ef=10", "ef=32", "ef=64"] {
        assert!(out.contains(ef), "missing row {ef}: {out}");
    }
    assert!(out.contains("clamped"), "no ef<k clamp warning: {out}");

    // missing query spec is an error
    let (ok, _) = run(&["search", "--data", &data, "--graph", &graph]);
    assert!(!ok);
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn cli_sharded_serving() {
    // ooc-build -> serve-bench --shards / search --shards: the shard
    // directory is servable without the assembled graph file.
    let dir = tmpdir();
    let data = dir.join("d.dsb").to_string_lossy().into_owned();
    let graph = dir.join("g.knng").to_string_lossy().into_owned();
    let shard_dir = dir.join("shards").to_string_lossy().into_owned();

    let (ok, out) = run(&["gen-data", "--name", "clustered", "--n", "600", "--out", &data]);
    assert!(ok, "gen-data failed: {out}");
    let (ok, out) = run(&[
        "ooc-build", "--data", &data, "--dir", &shard_dir, "--shards", "3",
        "--workers", "2", "--out", &graph, "--set", "k=10", "--set", "p=5",
        "--set", "max_iter=5",
    ]);
    assert!(ok, "ooc-build failed: {out}");
    let sd = std::path::Path::new(&shard_dir);
    assert!(sd.join("manifest.json").exists(), "no manifest written");
    assert!(sd.join("stats.json").exists(), "no stats written");

    // serve-bench over the shard directory, queries from the original
    let (ok, out) = run(&[
        "serve-bench", "--shards", &shard_dir, "--data", &data, "--ef", "16,64",
        "--queries", "100", "--distinct", "50", "--threads", "2",
    ]);
    assert!(ok, "sharded serve-bench failed: {out}");
    assert!(out.contains("recall@10"), "no recall column: {out}");
    assert!(out.contains("sharded"), "index description missing: {out}");
    for ef in ["ef=16", "ef=64"] {
        assert!(out.contains(ef), "missing row {ef}: {out}");
    }

    // ... and without --data (corpus re-assembled from the shards)
    let (ok, out) = run(&[
        "serve-bench", "--shards", &shard_dir, "--ef", "32", "--queries", "60",
        "--distinct", "30", "--threads", "2",
    ]);
    assert!(ok, "sharded serve-bench without --data failed: {out}");
    assert!(out.contains("ef=32"), "missing row: {out}");

    // single query + probe limit through the sharded index
    let (ok, out) = run(&[
        "search", "--shards", &shard_dir, "--query-id", "7", "--k", "5", "--ef", "32",
        "--probe-shards", "2",
    ]);
    assert!(ok, "sharded search failed: {out}");
    assert!(out.contains("top-5"), "unexpected search output: {out}");

    // --graph and --shards together is an error
    let (ok, _) = run(&[
        "search", "--shards", &shard_dir, "--graph", &graph, "--query-id", "1",
    ]);
    assert!(!ok);
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn cli_sharded_residency_budget_and_probe_clamp() {
    let dir = tmpdir();
    let data = dir.join("d.dsb").to_string_lossy().into_owned();
    let graph = dir.join("g.knng").to_string_lossy().into_owned();
    let shard_dir = dir.join("shards").to_string_lossy().into_owned();

    let (ok, out) = run(&["gen-data", "--name", "clustered", "--n", "600", "--out", &data]);
    assert!(ok, "gen-data failed: {out}");
    let (ok, out) = run(&[
        "ooc-build", "--data", &data, "--dir", &shard_dir, "--shards", "4",
        "--workers", "2", "--out", &graph, "--set", "k=10", "--set", "p=5",
        "--set", "max_iter=5",
    ]);
    assert!(ok, "ooc-build failed: {out}");

    // a ~0.02 MB budget fits less than one of these shards: the sweep
    // must still complete, report residency counters with evictions,
    // and fold them into stats.json
    let (ok, out) = run(&[
        "serve-bench", "--shards", &shard_dir, "--data", &data, "--ef", "32",
        "--queries", "60", "--distinct", "30", "--threads", "2",
        "--memory-budget", "0.02", "--search-threads", "2",
    ]);
    assert!(ok, "budget serve-bench failed: {out}");
    assert!(out.contains("recall@10"), "no recall column: {out}");
    assert!(out.contains("residency:"), "no residency block: {out}");
    assert!(out.contains("\"evictions\""), "no eviction counter: {out}");
    let stats_text =
        std::fs::read_to_string(std::path::Path::new(&shard_dir).join("stats.json")).unwrap();
    assert!(stats_text.contains("\"residency\""), "stats.json not folded: {stats_text}");
    assert!(stats_text.contains("\"merges\""), "build stats lost in fold: {stats_text}");

    // phantom --probe-shards clamps with a warning instead of probing
    // shards that do not exist
    let (ok, out) = run(&[
        "search", "--shards", &shard_dir, "--query-id", "3", "--k", "5",
        "--probe-shards", "99",
    ]);
    assert!(ok, "clamped search failed: {out}");
    assert!(out.contains("clamped"), "no probe clamp warning: {out}");
    assert!(out.contains("top-5"), "clamped search did not answer: {out}");
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn cli_search_threads_zero_is_clamped_and_open_loop_serve_bench_reports_queue() {
    let dir = tmpdir();
    let data = dir.join("d.dsb").to_string_lossy().into_owned();
    let graph = dir.join("g.knng").to_string_lossy().into_owned();
    let shard_dir = dir.join("shards").to_string_lossy().into_owned();

    let (ok, out) = run(&["gen-data", "--name", "clustered", "--n", "400", "--out", &data]);
    assert!(ok, "gen-data failed: {out}");
    let (ok, out) = run(&[
        "ooc-build", "--data", &data, "--dir", &shard_dir, "--shards", "3",
        "--workers", "2", "--out", &graph, "--set", "k=10", "--set", "p=5",
        "--set", "max_iter=4",
    ]);
    assert!(ok, "ooc-build failed: {out}");

    // --search-threads 0 clamps to 1 with a warning instead of being
    // silently masked at query time
    let (ok, out) = run(&[
        "search", "--shards", &shard_dir, "--query-id", "3", "--k", "5",
        "--search-threads", "0",
    ]);
    assert!(ok, "clamped search failed: {out}");
    assert!(
        out.contains("search-threads") && out.contains("clamped"),
        "no search-threads clamp warning: {out}"
    );
    assert!(out.contains("top-5"), "clamped search did not answer: {out}");

    // open-loop serve-bench: rows gain rate/queue/overload columns and
    // the sweep is folded into the shard directory's stats.json
    let (ok, out) = run(&[
        "serve-bench", "--shards", &shard_dir, "--data", &data, "--ef", "16,32",
        "--queries", "60", "--distinct", "30", "--threads", "2",
        "--search-threads", "2", "--arrival-rate", "300", "--arrival", "poisson",
    ]);
    assert!(ok, "open-loop serve-bench failed: {out}");
    for col in ["rate", "queue_p50_ms", "queue_p99_ms", "overload"] {
        assert!(out.contains(col), "missing open-loop column {col}: {out}");
    }
    let stats_text =
        std::fs::read_to_string(std::path::Path::new(&shard_dir).join("stats.json")).unwrap();
    for key in ["\"serve\"", "\"queue_p50_ms\"", "\"queue_p99_ms\"", "\"overload\"", "\"rate\""]
    {
        assert!(stats_text.contains(key), "stats.json missing {key}: {stats_text}");
    }

    // an unparseable arrival process is rejected
    let (ok, out) = run(&[
        "serve-bench", "--shards", &shard_dir, "--data", &data, "--ef", "16",
        "--queries", "10", "--distinct", "10", "--arrival-rate", "100",
        "--arrival", "bursty",
    ]);
    assert!(!ok, "unknown arrival process must be rejected: {out}");
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn cli_block_residency_serves_under_sub_shard_budget() {
    let dir = tmpdir();
    let data = dir.join("d.dsb").to_string_lossy().into_owned();
    let graph = dir.join("g.knng").to_string_lossy().into_owned();
    let shard_dir = dir.join("shards").to_string_lossy().into_owned();

    let (ok, out) = run(&["gen-data", "--name", "clustered", "--n", "600", "--out", &data]);
    assert!(ok, "gen-data failed: {out}");
    let (ok, out) = run(&[
        "ooc-build", "--data", &data, "--dir", &shard_dir, "--shards", "4",
        "--workers", "2", "--out", &graph, "--set", "k=10", "--set", "p=5",
        "--set", "max_iter=5",
    ]);
    assert!(ok, "ooc-build failed: {out}");

    // the same query under whole-shard (unbounded) and block residency
    // with a budget far below one shard: identical answer lines, and
    // the block run must not emit the probe-vs-budget pin warning
    // (block pins are handles, not shard data)
    let q = ["search", "--shards", &shard_dir, "--query-id", "7", "--k", "5", "--ef", "32"];
    let (ok, out_shard) = run(&q);
    assert!(ok, "shard-mode search failed: {out_shard}");
    let (ok, out_block) = run(&[
        "search", "--shards", &shard_dir, "--query-id", "7", "--k", "5", "--ef", "32",
        "--residency", "block", "--memory-budget", "0.02", "--block-size", "4",
    ]);
    assert!(ok, "block-mode search failed: {out_block}");
    assert!(!out_block.contains("can pin"), "block mode must not warn about pins: {out_block}");
    let answers = |text: &str| -> Vec<String> {
        text.lines().filter(|l| l.contains("dist=")).map(|l| l.trim().to_string()).collect()
    };
    let (a, b) = (answers(&out_shard), answers(&out_block));
    assert_eq!(a.len(), 5, "unexpected result shape: {out_shard}");
    assert_eq!(a, b, "block residency changed the answers:\n{out_shard}\nvs\n{out_block}");

    // serve-bench in block mode folds block counters into stats.json
    let (ok, out) = run(&[
        "serve-bench", "--shards", &shard_dir, "--data", &data, "--ef", "32",
        "--queries", "60", "--distinct", "30", "--threads", "2",
        "--residency", "block", "--memory-budget", "0.05",
    ]);
    assert!(ok, "block serve-bench failed: {out}");
    assert!(out.contains("residency=block"), "describe missing mode: {out}");
    assert!(out.contains("\"mode\": \"block\"") || out.contains("\"mode\":\"block\""),
        "residency json missing mode: {out}");
    let stats_text =
        std::fs::read_to_string(std::path::Path::new(&shard_dir).join("stats.json")).unwrap();
    for key in ["\"block_fetches\"", "\"bytes_read\"", "\"rejected_admissions\""] {
        assert!(stats_text.contains(key), "stats.json missing {key}: {stats_text}");
    }

    // an unknown residency mode is rejected
    let (ok, out) = run(&[
        "search", "--shards", &shard_dir, "--query-id", "1", "--residency", "mmap",
    ]);
    assert!(!ok, "unknown residency mode must be rejected: {out}");
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn cli_telemetry_trace_and_metrics_export() {
    let dir = tmpdir();
    let data = dir.join("d.dsb").to_string_lossy().into_owned();
    let graph = dir.join("g.knng").to_string_lossy().into_owned();
    let shard_dir = dir.join("shards").to_string_lossy().into_owned();
    let traces = dir.join("traces.jsonl").to_string_lossy().into_owned();
    let metrics = dir.join("metrics.jsonl").to_string_lossy().into_owned();

    let (ok, out) = run(&["gen-data", "--name", "clustered", "--n", "500", "--out", &data]);
    assert!(ok, "gen-data failed: {out}");
    let (ok, out) = run(&[
        "ooc-build", "--data", &data, "--dir", &shard_dir, "--shards", "3",
        "--workers", "2", "--out", &graph, "--set", "k=10", "--set", "p=5",
        "--set", "max_iter=5",
    ]);
    assert!(ok, "ooc-build failed: {out}");

    // block-residency sweep with every 4th query traced and per-point
    // registry snapshots exported
    let (ok, out) = run(&[
        "serve-bench", "--shards", &shard_dir, "--data", &data, "--ef", "16,32",
        "--queries", "40", "--distinct", "20", "--threads", "2",
        "--residency", "block", "--trace-sample", "4",
        "--trace-out", &traces, "--metrics-out", &metrics,
    ]);
    assert!(ok, "telemetry serve-bench failed: {out}");
    assert!(out.contains("sampled traces ->"), "no trace summary line: {out}");
    assert!(out.contains("metric points ->"), "no metrics summary line: {out}");
    // the sweep rows grew mean work columns
    assert!(out.contains("dist_evals") && out.contains("hops"), "no work columns: {out}");

    // traces: 40 queries sampled every 4th, per ef point -> 10 x 2
    let ttext = std::fs::read_to_string(&traces).unwrap();
    assert_eq!(ttext.lines().count(), 20, "wrong trace count:\n{ttext}");
    assert!(ttext.contains("\"shards\""), "traces carry no spans:\n{ttext}");

    // metrics: one JSONL object per operating point
    let mtext = std::fs::read_to_string(&metrics).unwrap();
    assert_eq!(mtext.lines().count(), 2, "wrong metrics point count:\n{mtext}");
    assert!(mtext.contains("\"point\""), "no point label: {mtext}");
    assert!(mtext.contains("block_cache.fetches"), "no block counters: {mtext}");
    assert!(mtext.contains("query.service_us"), "no service histogram: {mtext}");

    // stats.json gained the registry snapshot next to build/serve stats
    let stats_text =
        std::fs::read_to_string(std::path::Path::new(&shard_dir).join("stats.json")).unwrap();
    assert!(stats_text.contains("\"telemetry\""), "no telemetry block: {stats_text}");
    assert!(stats_text.contains("query.dist_evals"), "no query work: {stats_text}");
    assert!(stats_text.contains("\"merges\""), "build stats lost in fold: {stats_text}");

    // the trace subcommand renders the aggregate report
    let (ok, out) = run(&["trace", &traces, "--top", "2"]);
    assert!(ok, "trace subcommand failed: {out}");
    assert!(out.contains("20 sampled queries"), "wrong report header: {out}");
    assert!(out.contains("slowest 2 queries:"), "no slowest section: {out}");
    assert!(out.contains("service_ms"), "no distribution table: {out}");

    // a missing trace file is an error, not an empty report
    let nope = dir.join("nope.jsonl").to_string_lossy().into_owned();
    let (ok, out) = run(&["trace", &nope]);
    assert!(!ok, "trace on a missing file must fail: {out}");
    std::fs::remove_dir_all(dir).ok();
}

/// Kills the `gnnd serve` child even when an assertion fails mid-test.
struct KillOnDrop(std::process::Child);

impl Drop for KillOnDrop {
    fn drop(&mut self) {
        let _ = self.0.kill();
        let _ = self.0.wait();
    }
}

#[test]
fn cli_serve_capacity_and_network_bench() {
    let dir = tmpdir();
    let data = dir.join("d.dsb").to_string_lossy().into_owned();
    let graph = dir.join("g.knng").to_string_lossy().into_owned();
    let stats = dir.join("server_stats.json").to_string_lossy().into_owned();

    let (ok, out) = run(&["gen-data", "--name", "clustered", "--n", "500", "--out", &data]);
    assert!(ok, "gen-data failed: {out}");
    let (ok, out) = run(&[
        "build", "--data", &data, "--out", &graph, "--set", "k=10", "--set", "p=5",
        "--set", "max_iter=5",
    ]);
    assert!(ok, "build failed: {out}");

    // bad values are rejected before any socket is bound
    let (ok, out) = run(&[
        "serve", "--data", &data, "--graph", &graph, "--listen", "127.0.0.1:0",
        "--coalesce-window", "abc",
    ]);
    assert!(!ok, "non-numeric --coalesce-window must be rejected: {out}");
    let (ok, out) = run(&[
        "serve", "--data", &data, "--graph", &graph, "--listen", "127.0.0.1:0",
        "--queue-limit", "-3",
    ]);
    assert!(!ok, "negative --queue-limit must be rejected: {out}");
    let (ok, out) = run(&["capacity", "--data", &data, "--graph", &graph, "--slo-ms", "0"]);
    assert!(!ok, "--slo-ms 0 must be rejected: {out}");
    assert!(out.contains("slo-ms"), "unhelpful error: {out}");
    let (ok, out) = run(&["capacity", "--data", &data, "--graph", &graph, "--iters", "0"]);
    assert!(!ok, "--iters 0 must be rejected: {out}");
    let (ok, out) = run(&["serve-bench", "--target", "127.0.0.1:1", "--ef", "32"]);
    assert!(!ok, "--target without --data must be rejected: {out}");
    assert!(out.contains("--data"), "unhelpful error: {out}");
    let (ok, out) = run(&[
        "serve-bench", "--target", "127.0.0.1:1", "--data", &data, "--shards", "/nope",
    ]);
    assert!(!ok, "--target with --shards must be rejected: {out}");

    // in-process capacity search prints the parseable rate lines
    let (ok, out) = run(&[
        "capacity", "--data", &data, "--graph", &graph, "--ef", "32", "--queries", "40",
        "--distinct", "20", "--threads", "2", "--iters", "2", "--slo-ms", "100",
    ]);
    assert!(ok, "capacity failed: {out}");
    assert!(out.contains("capacity_qps="), "no capacity line: {out}");
    assert!(out.contains("closed_loop_qps="), "no closed-loop line: {out}");

    // a real server process on an ephemeral port, announced on stdout
    let mut child = std::process::Command::new(bin())
        .args([
            "serve", "--data", &data, "--graph", &graph, "--listen", "127.0.0.1:0",
            "--coalesce-window", "200", "--queue-limit", "256", "--stats-out", &stats,
        ])
        .stdout(std::process::Stdio::piped())
        .stderr(std::process::Stdio::null())
        .spawn()
        .expect("spawn gnnd serve");
    let mut lines = std::io::BufReader::new(child.stdout.take().unwrap());
    let child = KillOnDrop(child);
    let addr = {
        use std::io::BufRead;
        let mut addr = None;
        for _ in 0..10 {
            let mut line = String::new();
            if lines.read_line(&mut line).unwrap() == 0 {
                break;
            }
            if let Some(rest) = line.trim().strip_prefix("listening on ") {
                addr = Some(rest.to_string());
                break;
            }
        }
        addr.expect("server never announced its address")
    };

    // the bench harness as a network client of the live server
    let (ok, out) = run(&[
        "serve-bench", "--target", &addr, "--data", &data, "--ef", "32", "--queries",
        "60", "--distinct", "30", "--threads", "2",
    ]);
    assert!(ok, "serve-bench --target failed: {out}");
    assert!(out.contains("recall@10"), "no recall column: {out}");
    assert!(out.contains("ef=32"), "missing row: {out}");
    assert!(out.contains("remote("), "index description must show the remote: {out}");
    assert!(out.contains("shed"), "no shed column: {out}");

    // capacity against the live server
    let (ok, out) = run(&[
        "capacity", "--target", &addr, "--data", &data, "--ef", "32", "--queries", "30",
        "--distinct", "15", "--threads", "2", "--iters", "1", "--slo-ms", "200",
    ]);
    assert!(ok, "capacity --target failed: {out}");
    assert!(out.contains("capacity_qps="), "no capacity line: {out}");

    // the stats sidecar survives a hard kill of the server process
    std::thread::sleep(std::time::Duration::from_millis(700));
    drop(child);
    let text = std::fs::read_to_string(&stats).expect("server wrote no stats file");
    assert!(text.contains("server.accepted"), "stats missing server counters: {text}");
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn cli_rejects_bad_input() {
    let (ok, _) = run(&["bogus-subcommand"]);
    assert!(!ok);
    let (ok, out) = run(&["build", "--data", "/nonexistent.dsb", "--out", "/tmp/x.knng"]);
    assert!(!ok);
    assert!(out.contains("error"), "no error message: {out}");
    let (ok, _) = run(&["gen-data", "--name", "nope", "--n", "10", "--out", "/tmp/x.dsb"]);
    assert!(!ok);
}

#[test]
fn cli_config_file_plus_overrides() {
    let dir = tmpdir();
    let cfg = dir.join("c.cfg");
    std::fs::write(&cfg, "k = 10\np = 5\nmax_iter = 4\n").unwrap();
    let data = dir.join("d.dsb").to_string_lossy().into_owned();
    let graph = dir.join("g.knng").to_string_lossy().into_owned();
    let (ok, _) = run(&["gen-data", "--name", "uniform", "--n", "300", "--out", &data]);
    assert!(ok);
    let (ok, out) = run(&[
        "build", "--data", &data, "--out", &graph,
        "--config", &cfg.to_string_lossy(), "--set", "k=14",
    ]);
    assert!(ok, "{out}");
    assert!(out.contains("k=14"), "override not applied: {out}");
    std::fs::remove_dir_all(dir).ok();
}
