//! End-to-end driver — proves all three layers compose on a real
//! workload (the EXPERIMENTS.md headline run).
//!
//! Pipeline: synthesize a SIFT-shaped corpus -> exact ground truth ->
//! GNND build over the **PJRT engine** (the AOT-compiled XLA artifact
//! with the Pallas cross-matching kernels inside; requires
//! `make artifacts`, falls back to the native engine with a warning) ->
//! recall@10 + wall time vs single-thread classic NN-Descent and the
//! exact brute-force reference — the paper's Fig.-6 protocol on one
//! dataset.
//!
//! ```bash
//! make artifacts && cargo run --release --example e2e_pipeline
//! GNND_E2E_N=60000 cargo run --release --example e2e_pipeline   # bigger corpus
//! ```

use gnnd::baselines::nn_descent::{self, NnDescentParams};
use gnnd::config::EngineKind;
use gnnd::dataset::{groundtruth, synth};
use gnnd::gnnd::{build_with_stats, GnndParams};
use gnnd::metrics::{recall_at, Report, Row};
use gnnd::runtime;
use gnnd::util::timer::Timer;

fn main() -> gnnd::Result<()> {
    let n: usize = std::env::var("GNND_E2E_N")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(20_000);
    let ds = synth::sift_like(n, 0xE2E);
    println!("workload: {} ({} x {})", ds.name, ds.len(), ds.d);

    let t = Timer::start();
    let (ids, truth) = groundtruth::sampled_truth(&ds, 1000, 10, 0xE7A1);
    println!("ground truth (1000 sampled objects) in {:.1}s", t.secs());

    let mut report = Report::new("E2E pipeline (paper Fig. 6 protocol, sift-like)")
        .meta("n", ds.len())
        .meta("d", ds.d);

    // --- GNND over the PJRT artifact (the paper's on-device path) ---
    let engine_kind = if runtime::artifacts_available("artifacts") {
        EngineKind::Pjrt
    } else {
        eprintln!("WARNING: artifacts/ missing — run `make artifacts`; using native engine");
        EngineKind::Native
    };
    let params = GnndParams::default()
        .with_k(32)
        .with_p(16)
        .with_iters(10)
        .with_engine(engine_kind);
    let t = Timer::start();
    let out = build_with_stats(&ds, &params)?;
    let gnnd_secs = t.secs();
    let gnnd_recall = recall_at(&out.graph, &truth, Some(&ids), 10);
    println!(
        "gnnd[{}]: {:.2}s, recall@10 {:.4}, {} iters",
        out.stats.engine, gnnd_secs, gnnd_recall, out.stats.iters
    );
    for (phase, secs) in &out.stats.phases {
        println!("   {phase:<14} {secs:>9.3}s");
    }
    report.push(
        Row::new(format!("gnnd ({})", out.stats.engine))
            .col("time_s", gnnd_secs)
            .col("recall@10", gnnd_recall),
    );

    // --- native engine point for the same parameters (oracle parity) ---
    if engine_kind == EngineKind::Pjrt {
        let t = Timer::start();
        let native = build_with_stats(&ds, &params.clone().with_engine(EngineKind::Native))?;
        let r = recall_at(&native.graph, &truth, Some(&ids), 10);
        println!("gnnd[native]: {:.2}s, recall@10 {:.4}", t.secs(), r);
        report.push(Row::new("gnnd (native)").col("time_s", t.secs()).col("recall@10", r));
        assert!(
            (r - gnnd_recall).abs() < 0.05,
            "engines disagree: pjrt {gnnd_recall} vs native {r}"
        );
    }

    // --- classic single-thread NN-Descent (the paper's 100-250x baseline) ---
    let t = Timer::start();
    let (g_nd, nd_stats) = nn_descent::build(
        &ds,
        &NnDescentParams { k: 20, max_iter: 10, threads: 1, ..Default::default() },
    );
    let nd_secs = t.secs();
    let nd_recall = recall_at(&g_nd, &truth, Some(&ids), 10);
    println!(
        "nn-descent[1t]: {:.2}s, recall@10 {:.4} ({} iters, {:.1}M dist evals)",
        nd_secs,
        nd_recall,
        nd_stats.iters,
        nd_stats.distance_evals as f64 / 1e6
    );
    report.push(Row::new("nn-descent (1 thread)").col("time_s", nd_secs).col("recall@10", nd_recall));

    // --- headline ---
    let speedup = nd_secs / gnnd_secs;
    println!("\nheadline: GNND reaches recall@10 {gnnd_recall:.3} with {speedup:.1}x speedup over 1-thread NN-Descent");
    report.push(Row::new("speedup vs 1-thread").col("x", speedup));
    report.save_json("results")?;
    println!("{}", report.render());
    Ok(())
}
