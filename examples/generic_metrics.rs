//! Genericness demo: the paper stresses that NN-Descent (and its GNND
//! redesign) works "in generic metric space" — unlike the
//! space-partitioning competitors that require l_p norms. This example
//! builds graphs under squared-L2, cosine (GloVe-shaped text
//! embeddings, the paper's non-l2 benchmark) and raw inner product,
//! with identical coordinator code — only the metric changes.
//!
//! ```bash
//! cargo run --release --example generic_metrics
//! ```

use gnnd::config::Metric;
use gnnd::dataset::{groundtruth, synth, Dataset};
use gnnd::gnnd::{build, GnndParams};
use gnnd::metrics::recall_at;
use gnnd::util::timer::Timer;

fn run(ds: &Dataset) -> gnnd::Result<()> {
    let params = GnndParams::default().with_k(20).with_p(10).with_iters(8);
    let t = Timer::start();
    let g = build(ds, &params)?;
    let (ids, truth) = groundtruth::sampled_truth(ds, 500, 10, 5);
    let r = recall_at(&g, &truth, Some(&ids), 10);
    println!(
        "{:<22} metric={:<7} d={:<4} -> recall@10 {:.4} in {:.2}s",
        ds.name,
        ds.metric.to_string(),
        ds.d,
        r,
        t.secs()
    );
    Ok(())
}

fn main() -> gnnd::Result<()> {
    println!("same coordinator, three metrics (paper: genericness preserved):\n");
    // 1. squared L2 on SIFT-shaped data
    run(&synth::sift_like(8_000, 1))?;

    // 2. cosine on GloVe-shaped embeddings (normalize-once + negated IP)
    run(&synth::glove_like(8_000, 2))?;

    // 3. raw (maximum) inner product on unnormalized embeddings
    let glove = synth::glove_like(8_000, 3);
    let ip = Dataset::new("glove-raw-ip", glove.d, Metric::Ip, glove.raw().to_vec());
    run(&ip)?;
    Ok(())
}
