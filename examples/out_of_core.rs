//! Out-of-core construction demo (paper §5, Table-2 pipeline): the
//! dataset is partitioned into shards spilled to disk, GNND builds each
//! sub-graph, and GGM pairwise-merges them with overlapped disk I/O —
//! at no point is more than a couple of shards memory-resident.
//!
//! ```bash
//! cargo run --release --example out_of_core
//! GNND_OOC_N=100000 GNND_OOC_SHARDS=16 cargo run --release --example out_of_core
//! ```

use gnnd::dataset::{groundtruth, synth};
use gnnd::gnnd::{build, GnndParams, NativeEngine};
use gnnd::merge::outofcore::{build_out_of_core, OutOfCoreConfig};
use gnnd::metrics::recall_at;
use gnnd::util::timer::Timer;

fn env_or(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() -> gnnd::Result<()> {
    let n = env_or("GNND_OOC_N", 24_000);
    let shards = env_or("GNND_OOC_SHARDS", 8);
    let workers = env_or("GNND_OOC_WORKERS", 2);
    let ds = synth::deep_like(n, 0x00C);
    println!(
        "out-of-core build: {} ({} x {}), {shards} shards, {workers} merge workers",
        ds.name,
        ds.len(),
        ds.d
    );

    let params = GnndParams::default().with_k(20).with_p(10).with_iters(8);
    let cfg = OutOfCoreConfig { shards, workers, params: params.clone() };
    let dir = std::env::temp_dir().join(format!("gnnd-ooc-example-{}", std::process::id()));

    let t = Timer::start();
    let (graph, stats) = build_out_of_core(&ds, &dir, &cfg, &NativeEngine)?;
    let total = t.secs();
    println!(
        "done in {total:.2}s: shard spill+builds {:.2}s, {} pairwise merges over {} rounds {:.2}s",
        stats.build_secs, stats.merges, stats.rounds, stats.merge_secs
    );

    let (ids, truth) = groundtruth::sampled_truth(&ds, 800, 10, 2);
    let r_ooc = recall_at(&graph, &truth, Some(&ids), 10);
    println!("recall@10 (out-of-core)  = {r_ooc:.4}");

    // reference: the same parameters fully in memory
    let t = Timer::start();
    let g_mem = build(&ds, &params)?;
    let r_mem = recall_at(&g_mem, &truth, Some(&ids), 10);
    println!("recall@10 (in-memory)    = {r_mem:.4}  ({:.2}s)", t.secs());
    println!(
        "quality gap: {:.3} — the paper's claim is that sharded GGM construction \
         approaches in-memory quality while never holding the dataset",
        r_mem - r_ooc
    );
    std::fs::remove_dir_all(dir).ok();
    Ok(())
}
