//! Incremental construction demo (paper §5.1): data arrives in batches;
//! each batch gets a GNND sub-graph which GGM folds into the running
//! graph — "as the new data come in, GNND is called to build a
//! sub-graph on the first hand. Thereafter, GGM is called to join this
//! new sub-graph into the existing k-NN graph."
//!
//! ```bash
//! cargo run --release --example incremental
//! ```

use gnnd::dataset::{groundtruth, synth, Dataset};
use gnnd::gnnd::{build, GnndParams, NativeEngine};
use gnnd::merge::incremental_add;
use gnnd::metrics::recall_at;
use gnnd::util::timer::Timer;

fn main() -> gnnd::Result<()> {
    let total_n = 20_000;
    let batches = 4;
    let full = synth::sift_like(total_n, 0x1AC);
    let params = GnndParams::default().with_k(20).with_p(10).with_iters(8);

    // first batch: plain GNND build
    let step = total_n / (batches + 1);
    let ids0: Vec<usize> = (0..step).collect();
    let first = full.select(&ids0, "stream[0]");
    let t = Timer::start();
    let mut graph = build(&first, &params)?;
    println!("batch 0: built {} objects in {:.2}s", step, t.secs());

    let mut have = step;
    for b in 1..=batches {
        let upto = ((b + 1) * step).min(total_n);
        let ids: Vec<usize> = (0..upto).collect();
        let current: Dataset = full.select(&ids, format!("stream[0..{b}]"));
        let t = Timer::start();
        let (g, stats) = incremental_add(&current, have, &graph, &params, &NativeEngine)?;
        graph = g;
        have = upto;
        // quality so far
        let (qids, truth) = groundtruth::sampled_truth(&current, 500, 10, b as u64);
        let r = recall_at(&graph, &truth, Some(&qids), 10);
        println!(
            "batch {b}: +{} objects in {:.2}s ({} refine iters) -> total {}, recall@10 {:.4}",
            upto - (b * step).min(total_n),
            t.secs(),
            stats.iters,
            have,
            r
        );
    }

    // compare the final incremental graph against a from-scratch build
    let (qids, truth) = groundtruth::sampled_truth(&full, 800, 10, 99);
    let r_inc = recall_at(&graph, &truth, Some(&qids), 10);
    let t = Timer::start();
    let scratch = build(&full, &params)?;
    let r_scr = recall_at(&scratch, &truth, Some(&qids), 10);
    println!(
        "\nfinal: incremental recall@10 {r_inc:.4} vs from-scratch {r_scr:.4} ({:.2}s rebuild)",
        t.secs()
    );
    Ok(())
}
