//! Search service demo: the full serve path over a constructed graph —
//! build once, then answer online ANN queries (single, batched, and a
//! closed-loop recall-vs-QPS sweep). Everything after the build is the
//! serving subsystem; the graph could equally come from `gnnd build`,
//! a GGM merge, or the out-of-core pipeline via `KnnGraph::load`.
//!
//! ```bash
//! cargo run --release --example search_service
//! GNND_SEARCH_N=50000 cargo run --release --example search_service
//! ```

use gnnd::dataset::synth;
use gnnd::gnnd::{build, GnndParams};
use gnnd::search::batch::BatchExecutor;
use gnnd::search::serve::{self, ServeConfig};
use gnnd::search::{EntryStrategy, SearchIndex, SearchParams};
use gnnd::util::timer::Timer;

fn main() -> gnnd::Result<()> {
    let n: usize = std::env::var("GNND_SEARCH_N")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(10_000);

    // 1. offline: construct the k-NN graph (the index structure)
    let ds = synth::sift_like(n, 0x5E2C);
    let t = Timer::start();
    let graph = build(&ds, &GnndParams::default())?;
    println!("index built: {} objects, k={} in {:.1}s", graph.n(), graph.k(), t.secs());

    // 2. online: wrap it in a SearchIndex (entry selection only — no
    //    copies, any loaded graph serves the same way)
    let params = SearchParams::default()
        .with_ef(64)
        .with_entries(EntryStrategy::KMeans, 16);
    let index = SearchIndex::new(&ds, &graph, params)?;
    println!("entry points: {:?}", index.entries());

    // 3. a single query with a warm scratch (the zero-allocation path)
    let mut scratch = index.make_scratch();
    let mut hits = Vec::new();
    let t = Timer::start();
    index.search_into_excluding(ds.vec(0), 10, 0, &mut scratch, &mut hits);
    println!(
        "query 0: top-10 in {:.3} ms ({} distance evals, {} hops)",
        t.ms(),
        scratch.dist_evals,
        scratch.hops
    );
    for (rank, (d, id)) in hits.iter().enumerate() {
        println!("  {:>2}. id={id:<8} dist={d:.1}", rank + 1);
    }

    // 4. a batch of queries fanned across worker threads
    let nq = 1_000.min(n);
    let mut qbuf = Vec::with_capacity(nq * ds.d);
    for q in 0..nq {
        qbuf.extend_from_slice(ds.vec(q));
    }
    let exec = BatchExecutor::new(&index, 0);
    let t = Timer::start();
    let results = exec.run(&qbuf, ds.d, 10);
    let secs = t.secs();
    println!(
        "batched: {} queries on {} threads in {:.2}s ({:.0} qps)",
        results.len(),
        exec.threads(),
        secs,
        results.len() as f64 / secs.max(1e-9)
    );

    // 5. the operating curve: recall vs QPS over an ef sweep — the
    //    harness only sees `&dyn AnnIndex`, so a sharded index (see
    //    examples/out_of_core.rs + `gnnd serve-bench --shards`) plugs
    //    into the same sweep
    let cfg = ServeConfig {
        ef_sweep: vec![16, 32, 128],
        n_queries: 1_000.min(n),
        distinct_queries: 500.min(n),
        ..Default::default()
    };
    let sweep_index = SearchIndex::new(&ds, &graph, cfg.params.clone())?;
    let report = serve::run_sweep_on(&sweep_index, &ds, &cfg)?;
    println!("{}", report.render());
    Ok(())
}
