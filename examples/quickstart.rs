//! Quickstart: build an approximate k-NN graph in a few lines.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use gnnd::dataset::{groundtruth, synth};
use gnnd::gnnd::{build_with_stats, GnndParams};
use gnnd::metrics::recall_at;
use gnnd::util::timer::Timer;

fn main() -> gnnd::Result<()> {
    // 1. a dataset: 10k SIFT-shaped vectors (or load your own fvecs/dsb
    //    via gnnd::dataset::io)
    let ds = synth::sift_like(10_000, 0xC0FFEE);
    println!("dataset: {} ({} x {}, metric {})", ds.name, ds.len(), ds.d, ds.metric);

    // 2. build the graph (paper Algorithm 1; defaults: k=32, p=16,
    //    selective update + multiple spinlocks)
    let params = GnndParams::default();
    let t = Timer::start();
    let out = build_with_stats(&ds, &params)?;
    println!(
        "built k={} graph in {:.2}s ({} iterations, engine={})",
        out.graph.k(),
        t.secs(),
        out.stats.iters,
        out.stats.engine,
    );
    for (phase, secs) in &out.stats.phases {
        println!("   {phase:<14} {secs:>8.3}s");
    }

    // 3. evaluate against exact ground truth on a 500-object sample
    let (ids, truth) = groundtruth::sampled_truth(&ds, 500, 10, 1);
    let recall = recall_at(&out.graph, &truth, Some(&ids), 10);
    println!("recall@10 = {recall:.4}   phi(G) = {:.4e}", out.graph.phi());

    // 4. the neighbor list of object 0
    let head: Vec<(u32, f32)> = out
        .graph
        .list(0)
        .iter()
        .take(5)
        .map(|e| (e.id, e.dist))
        .collect();
    println!("object 0 nearest 5: {head:?}");
    Ok(())
}
