"""L2 semantics: crossmatch / bruteforce vs a numpy oracle.

The oracle re-implements the paper's Algorithm-2 selection rules (masked
nearest-object reductions) with plain numpy loops, so these tests pin the
*semantics* the Rust coordinator depends on: id masking, merge-mode subset
masking, -1 sentinels, ascending top-k, and padded-base masking.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model

settings.register_profile("model", deadline=None, max_examples=20)
settings.load_profile("model")

BIG = float(model.MASKED)


def _oracle_crossmatch(nv, ni, ov, oi, metric="l2"):
    b, s, _ = nv.shape

    def dist(u, v):
        if metric == "l2":
            return float(np.sum((u - v) ** 2))
        return float(-np.dot(u, v))

    nn_i = -np.ones((b, s), np.int32)
    nn_d = np.full((b, s), BIG, np.float32)
    no_i = -np.ones((b, s), np.int32)
    no_d = np.full((b, s), BIG, np.float32)
    on_i = -np.ones((b, s), np.int32)
    on_d = np.full((b, s), BIG, np.float32)
    for bb in range(b):
        for i in range(s):
            if ni[bb, i] < 0:
                continue
            for j in range(s):
                if ni[bb, j] < 0 or ni[bb, i] == ni[bb, j]:
                    continue
                d = dist(nv[bb, i], nv[bb, j])
                if d < nn_d[bb, i]:
                    nn_d[bb, i], nn_i[bb, i] = d, j
            for j in range(s):
                if oi[bb, j] < 0 or ni[bb, i] == oi[bb, j]:
                    continue
                d = dist(nv[bb, i], ov[bb, j])
                if d < no_d[bb, i]:
                    no_d[bb, i], no_i[bb, i] = d, j
                if d < on_d[bb, j]:
                    on_d[bb, j], on_i[bb, j] = d, i
    return nn_i, nn_d, no_i, no_d, on_i, on_d


def _check_against_oracle(nv, ni, ov, oi, metric):
    got = [np.asarray(o) for o in model.crossmatch(nv, ni, ov, oi, metric=metric)]
    want = _oracle_crossmatch(nv, ni, ov, oi, metric=metric)
    for g_idx, g_d, w_idx, w_d, tag in (
        (got[0], got[1], want[0], want[1], "nn"),
        (got[2], got[3], want[2], want[3], "no"),
        (got[4], got[5], want[4], want[5], "on"),
    ):
        # Index ties can differ; distances must match, sentinels must match.
        np.testing.assert_array_equal(g_idx < 0, w_idx < 0, err_msg=tag)
        live = w_idx >= 0
        np.testing.assert_allclose(
            g_d[live], w_d[live], rtol=1e-3, atol=1e-2, err_msg=tag
        )


@given(
    b=st.integers(1, 4),
    s=st.integers(1, 12),
    d=st.integers(2, 80),
    metric=st.sampled_from(["l2", "ip"]),
    id_hi=st.sampled_from([3, 50, 10**6]),
    seed=st.integers(0, 2**31 - 1),
)
def test_crossmatch_matches_oracle(b, s, d, metric, id_hi, seed):
    rng = np.random.default_rng(seed)
    nv = rng.normal(size=(b, s, d)).astype(np.float32)
    ov = rng.normal(size=(b, s, d)).astype(np.float32)
    # small id_hi forces many duplicate-id masks; occasional -1 slots.
    ni = rng.integers(-1, id_hi, size=(b, s)).astype(np.int32)
    oi = rng.integers(-1, id_hi, size=(b, s)).astype(np.int32)
    _check_against_oracle(nv, ni, ov, oi, metric)


def test_crossmatch_merge_mode_masks_same_subset():
    """ids = subset labels: same-subset pairs must never be selected."""
    rng = np.random.default_rng(3)
    b, s, d = 2, 8, 16
    nv = rng.normal(size=(b, s, d)).astype(np.float32)
    ov = rng.normal(size=(b, s, d)).astype(np.float32)
    ni = np.tile(np.array([0, 0, 0, 0, 1, 1, 1, 1], np.int32), (b, 1))
    oi = np.tile(np.array([0, 0, 1, 1, 0, 0, 1, 1], np.int32), (b, 1))
    nn_i, nn_d, no_i, no_d, on_i, on_d = [
        np.asarray(o) for o in model.crossmatch(nv, ni, ov, oi)
    ]
    for bb in range(b):
        for i in range(s):
            if nn_i[bb, i] >= 0:
                assert ni[bb, nn_i[bb, i]] != ni[bb, i]
            if no_i[bb, i] >= 0:
                assert oi[bb, no_i[bb, i]] != ni[bb, i]
            if on_i[bb, i] >= 0:
                assert ni[bb, on_i[bb, i]] != oi[bb, i]


def test_crossmatch_all_invalid_returns_sentinels():
    b, s, d = 1, 4, 8
    nv = np.zeros((b, s, d), np.float32)
    ni = -np.ones((b, s), np.int32)
    out = [np.asarray(o) for o in model.crossmatch(nv, ni, nv, ni)]
    assert (out[0] == -1).all() and (out[2] == -1).all() and (out[4] == -1).all()
    assert (out[1] >= BIG / 2).all()


def test_crossmatch_single_new_sample_has_no_nn():
    """With one NEW sample there is no *other* NEW sample."""
    rng = np.random.default_rng(4)
    nv = rng.normal(size=(1, 1, 8)).astype(np.float32)
    ni = np.array([[5]], np.int32)
    out = [np.asarray(o) for o in model.crossmatch(nv, ni, nv, ni)]
    assert out[0][0, 0] == -1  # nn
    # old list holds the same object id -> also masked.
    assert out[2][0, 0] == -1  # no


@given(
    q=st.integers(1, 20),
    n=st.integers(1, 100),
    d=st.integers(2, 64),
    k=st.sampled_from([1, 5, 16]),
    seed=st.integers(0, 2**31 - 1),
)
def test_bruteforce_topk_matches_numpy(q, n, d, k, seed):
    rng = np.random.default_rng(seed)
    qs = rng.normal(size=(q, d)).astype(np.float32)
    base = rng.normal(size=(n, d)).astype(np.float32)
    valid = np.ones(n, np.float32)
    idx, dist = [np.asarray(o) for o in model.bruteforce(qs, base, valid, k=k)]
    full = np.sum((qs[:, None, :] - base[None, :, :]) ** 2, axis=-1)
    for i in range(q):
        order = np.argsort(full[i], kind="stable")[:k]
        live = min(k, n)
        np.testing.assert_allclose(
            dist[i, :live], np.sort(full[i])[:live], rtol=1e-3, atol=1e-2
        )
        assert (idx[i, live:] == -1).all()
        # ascending
        assert (np.diff(dist[i, :live]) >= -1e-4).all()
        # set equality modulo distance ties
        got_d = np.sort(full[i][idx[i, :live]])
        np.testing.assert_allclose(got_d, np.sort(full[i])[:live], rtol=1e-3, atol=1e-2)
        del order


def test_bruteforce_padding_masked():
    """Padded (valid=0) base rows must never appear in the top-k."""
    rng = np.random.default_rng(5)
    qs = rng.normal(size=(3, 16)).astype(np.float32)
    base = np.zeros((10, 16), np.float32)  # zero rows would win unmasked
    base[:4] = rng.normal(size=(4, 16)) * 10.0
    valid = np.zeros(10, np.float32)
    valid[:4] = 1.0
    idx, dist = [np.asarray(o) for o in model.bruteforce(qs, base, valid, k=8)]
    assert ((idx < 4) | (idx == -1)).all()
    assert (idx[:, :4] >= 0).all() and (idx[:, 4:] == -1).all()
