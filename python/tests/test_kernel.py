"""L1 correctness: Pallas kernels vs the pure-jnp oracle (ref.py).

Hypothesis sweeps shapes, dtypes and block sizes; the kernels must match
``ref.pairwise_ref`` to f32 tolerance everywhere. This is the CORE
correctness signal of the compile path: the AOT crossmatch/bruteforce
artifacts embed exactly these kernels.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.pairwise import pairwise_batched, pairwise_tiled
from compile.kernels.ref import pairwise_ref

settings.register_profile("kernels", deadline=None, max_examples=25)
settings.load_profile("kernels")


def _rand(rng, shape, dtype, scale):
    a = rng.normal(size=shape, loc=0.0, scale=scale)
    return a.astype(dtype)


def _tol(d, scale):
    # f32 matmul-expansion error grows with D and magnitude^2.
    return 1e-3 * max(1.0, scale * scale) * max(1.0, d / 64.0)


@given(
    b=st.integers(1, 5),
    s=st.integers(1, 40),
    t=st.integers(1, 40),
    d=st.integers(1, 300),
    metric=st.sampled_from(["l2", "ip"]),
    dtype=st.sampled_from([np.float32, np.float64, np.float16]),
    scale=st.sampled_from([0.1, 1.0, 30.0]),
    seed=st.integers(0, 2**31 - 1),
)
def test_pairwise_batched_matches_ref(b, s, t, d, metric, dtype, scale, seed):
    rng = np.random.default_rng(seed)
    x = _rand(rng, (b, s, d), dtype, scale)
    y = _rand(rng, (b, t, d), dtype, scale)
    got = np.asarray(pairwise_batched(x, y, metric=metric))
    want = np.asarray(pairwise_ref(x, y, metric=metric))
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=_tol(d, scale))


@given(
    m=st.integers(1, 200),
    n=st.integers(1, 200),
    d=st.integers(1, 300),
    metric=st.sampled_from(["l2", "ip"]),
    bm=st.sampled_from([8, 32, 128]),
    bd=st.sampled_from([64, 128]),
    seed=st.integers(0, 2**31 - 1),
)
def test_pairwise_tiled_matches_ref(m, n, d, metric, bm, bd, seed):
    rng = np.random.default_rng(seed)
    x = _rand(rng, (m, d), np.float32, 1.0)
    y = _rand(rng, (n, d), np.float32, 1.0)
    got = np.asarray(
        pairwise_tiled(x, y, metric=metric, block_m=bm, block_n=bm, block_d=bd)
    )
    want = np.asarray(pairwise_ref(x, y, metric=metric))
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=_tol(d, 1.0))


def test_l2_self_distance_is_zero():
    rng = np.random.default_rng(7)
    x = rng.normal(size=(2, 6, 64)).astype(np.float32)
    d = np.asarray(pairwise_batched(x, x, metric="l2"))
    diag = d[:, np.arange(6), np.arange(6)]
    np.testing.assert_allclose(diag, 0.0, atol=1e-3)


def test_l2_symmetry():
    rng = np.random.default_rng(8)
    x = rng.normal(size=(1, 9, 33)).astype(np.float32)
    y = rng.normal(size=(1, 7, 33)).astype(np.float32)
    dxy = np.asarray(pairwise_batched(x, y, metric="l2"))[0]
    dyx = np.asarray(pairwise_batched(y, x, metric="l2"))[0]
    np.testing.assert_allclose(dxy, dyx.T, rtol=1e-4, atol=1e-3)


def test_l2_nonnegative_clamped_scale():
    rng = np.random.default_rng(9)
    x = rng.normal(size=(1, 16, 128)).astype(np.float32)
    d = np.asarray(pairwise_batched(x, x, metric="l2"))
    # matmul expansion can dip slightly below zero in f32; bound the dip.
    assert d.min() > -1e-2


def test_zero_padding_invariance():
    """Padding D with zeros must not change distances (both metrics)."""
    rng = np.random.default_rng(10)
    x = rng.normal(size=(1, 5, 60)).astype(np.float32)
    y = rng.normal(size=(1, 4, 60)).astype(np.float32)
    xp = np.pad(x, ((0, 0), (0, 0), (0, 68)))
    yp = np.pad(y, ((0, 0), (0, 0), (0, 68)))
    for metric in ("l2", "ip"):
        a = np.asarray(pairwise_batched(x, y, metric=metric))
        b = np.asarray(pairwise_batched(xp, yp, metric=metric))
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-3)


def test_unknown_metric_rejected():
    x = np.zeros((1, 2, 4), np.float32)
    with pytest.raises(ValueError):
        pairwise_batched(x, x, metric="l1")
    with pytest.raises(ValueError):
        pairwise_tiled(x[0], x[0], metric="cosine")
