"""AOT exporter: manifest format + HLO text round-trip sanity.

The Rust runtime's manifest parser is unit-tested against the same
format on its side (rust/src/runtime/manifest.rs); this test pins the
producer: every emitted line must carry the keys Rust requires, and the
HLO text must be non-trivial and name the entry computation.
"""

import os
import subprocess
import sys
import tempfile

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(__file__))))


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    # small artifacts only, to keep the test fast
    from compile import aot

    aot.main(["--out-dir", str(out), "--only", "crossmatch_s16_d32_l2,bruteforce_d32_l2"])
    return out


def test_manifest_lines_have_required_keys(built):
    text = (built / "manifest.txt").read_text().strip()
    lines = [l for l in text.splitlines() if l.strip()]
    assert len(lines) == 2
    for line in lines:
        kv = dict(tok.split("=", 1) for tok in line.split())
        assert kv["kind"] in ("crossmatch", "bruteforce")
        for key in ("name", "metric", "impl", "file", "d"):
            assert key in kv, f"missing {key} in {line}"
        assert (built / kv["file"]).exists()
        if kv["kind"] == "crossmatch":
            assert int(kv["b"]) > 0 and int(kv["s"]) > 0
        else:
            assert int(kv["q"]) > 0 and int(kv["n"]) > 0 and int(kv["k"]) > 0


def test_hlo_text_is_parseable_shape(built):
    hlo = (built / "crossmatch_s16_d32_l2.hlo.txt").read_text()
    assert "HloModule" in hlo
    assert "ENTRY" in hlo
    # the crossmatch program returns a 6-tuple
    assert hlo.count("s32[") > 0 and hlo.count("f32[") > 0


def test_only_filter_selects_subset(built):
    files = sorted(os.listdir(built))
    assert files == [
        "bruteforce_d32_l2.hlo.txt",
        "crossmatch_s16_d32_l2.hlo.txt",
        "manifest.txt",
    ]
