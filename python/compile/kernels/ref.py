"""Pure-jnp correctness oracles for the Pallas kernels.

These are the "obviously right" dense formulations the kernels are tested
against (pytest + hypothesis sweeps in python/tests/). They are also
lowered as the ``impl=jnp`` artifact variants so the Rust benches can
ablate Pallas-tiled vs plain-XLA distance evaluation.
"""

import jax.numpy as jnp


def pairwise_ref(x, y, metric: str = "l2"):
    """x[..., S, D], y[..., T, D] -> [..., S, T] distances (f32).

    ``l2`` is the *squared* euclidean distance computed the naive way
    (explicit difference), deliberately different from the kernel's
    matmul expansion so the test catches algebra mistakes.
    """
    x = x.astype(jnp.float32)
    y = y.astype(jnp.float32)
    if metric == "l2":
        diff = x[..., :, None, :] - y[..., None, :, :]
        return jnp.sum(diff * diff, axis=-1)
    if metric == "ip":
        return -jnp.einsum("...sd,...td->...st", x, y)
    raise ValueError(f"unknown metric {metric!r}")
