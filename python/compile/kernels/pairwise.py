"""L1 — tiled pairwise-distance Pallas kernels.

This is the paper's Fig. 3 "tiled distance calculation" rethought for the
TPU-shaped stack (DESIGN.md §Hardware-Adaptation):

* the CUDA shared-memory tile becomes a Pallas ``BlockSpec`` block staged
  through VMEM;
* the per-thread scalar accumulation loop becomes the MXU-friendly matmul
  form  ``||x - y||^2 = ||x||^2 + ||y||^2 - 2<x, y>``  evaluated one
  D-slab at a time (the paper's "Phase 1 / Phase 2" sliding over the
  dimension axis is exactly the K-dim grid axis here);
* the warp is gone: one grid step produces a whole S x T distance tile.

Two entry points:

``pairwise_batched(x[B,S,D], y[B,T,D])``
    One independent S x T distance tile per batch element -- the GNND
    cross-matching shape (B objects, S sampled neighbors each).

``pairwise_tiled(x[M,D], y[N,D])``
    Classic 2-D tiling over a large distance matrix -- the brute-force /
    ground-truth shape.

Kernels are always lowered with ``interpret=True``: the CPU PJRT client
cannot execute Mosaic custom-calls, and correctness on this testbed is
checked through the interpret path (see /opt/xla-example/README.md).
Supported metrics: ``l2`` (squared euclidean) and ``ip`` (negated inner
product, so that smaller is always closer). Cosine is served at L2 by
l2-normalizing inputs and using ``ip`` (see model.py).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

#: Default dimension-slab width. 128 matches the MXU systolic width and
#: keeps the per-step VMEM footprint small (see DESIGN.md VMEM estimate).
BLOCK_D = 128

METRICS = ("l2", "ip")


def _ceil_to(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def _pad_last(a, to: int):
    """Zero-pad the last axis of ``a`` up to length ``to``.

    Zero padding is exact for both supported metrics: padded coordinates
    contribute 0 to norms and to dot products.
    """
    d = a.shape[-1]
    if d == to:
        return a
    pad = [(0, 0)] * (a.ndim - 1) + [(0, to - d)]
    return jnp.pad(a, pad)


def _tile_update(x, y, metric: str):
    """Distance contribution of one D-slab for tiles x[S,BD], y[T,BD]."""
    dot = jnp.dot(x, y.T, preferred_element_type=jnp.float32)
    if metric == "l2":
        xn = jnp.sum(x * x, axis=-1)
        yn = jnp.sum(y * y, axis=-1)
        return xn[:, None] + yn[None, :] - 2.0 * dot
    # negated inner product: accumulating per-slab is exact.
    return -dot


def _batched_update(x, y, metric: str):
    """Distance contribution of one D-slab for blocks x[BB,S,BD], y[BB,T,BD]."""
    dot = jnp.einsum("bsd,btd->bst", x, y, preferred_element_type=jnp.float32)
    if metric == "l2":
        xn = jnp.sum(x * x, axis=-1)
        yn = jnp.sum(y * y, axis=-1)
        return xn[:, :, None] + yn[:, None, :] - 2.0 * dot
    return -dot


def _batched_kernel(x_ref, y_ref, o_ref, *, metric: str):
    """Grid = (B/BB, D/BD). Blocks: x[BB,S,BD] y[BB,T,BD] o[BB,S,T].

    The batch tile BB rides inside the block: one grid step evaluates a
    whole stack of object locals as a single batched contraction. This
    is both the MXU-friendly layout (batched (S,BD)x(BD,T) passes) and —
    critically for the CPU PJRT path — avoids lowering interpret-mode
    grids into long per-object while loops (§Perf L1 iteration 1:
    75x faster artifact at B=64).
    """
    k = pl.program_id(1)
    part = _batched_update(x_ref[...], y_ref[...], metric)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = part

    @pl.when(k != 0)
    def _acc():
        o_ref[...] += part


@functools.partial(jax.jit, static_argnames=("metric", "block_d", "block_b"))
def pairwise_batched(x, y, metric: str = "l2", block_d: int = None, block_b: int = None):
    """Per-batch pairwise distances: x[B,S,D], y[B,T,D] -> [B,S,T] f32.

    Each batch element is one "object local" of the paper: its sampled
    NEW/OLD neighbor vectors. S and T are small (<= 2p), so a stack of
    ``block_b`` whole S x T tiles lives in VMEM while the D axis is
    streamed in ``block_d`` slabs (VMEM estimate in DESIGN.md §Perf).

    Block defaults are **whole-axis** (grid = (1, 1)): interpret-mode
    Pallas lowers every extra grid step into a while-loop iteration with
    full-buffer dynamic slices, which costs ~7 ms/step on the CPU PJRT
    client (§Perf L1 iteration 5: 27.7 ms -> 0.18 ms per B=256 call).
    Real-TPU builds would pass block_b/block_d to fit VMEM — the tiling
    stays expressible; only the schedule parameter changes.
    """
    if metric not in METRICS:
        raise ValueError(f"unknown metric {metric!r}")
    b, s, d = x.shape
    t = y.shape[1]
    if y.shape[0] != b or y.shape[2] != d:
        raise ValueError(f"shape mismatch: {x.shape} vs {y.shape}")
    bb = min(block_b or b, b)
    bp = _ceil_to(b, bb)
    block_d = block_d or _ceil_to(d, 8)
    dp = _ceil_to(d, block_d)
    xp = _pad_last(x.astype(jnp.float32), dp)
    yp = _pad_last(y.astype(jnp.float32), dp)
    if bp != b:
        xp = jnp.pad(xp, ((0, bp - b), (0, 0), (0, 0)))
        yp = jnp.pad(yp, ((0, bp - b), (0, 0), (0, 0)))
    grid = (bp // bb, dp // block_d)
    out = pl.pallas_call(
        functools.partial(_batched_kernel, metric=metric),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, s, block_d), lambda i, k: (i, 0, k)),
            pl.BlockSpec((bb, t, block_d), lambda i, k: (i, 0, k)),
        ],
        out_specs=pl.BlockSpec((bb, s, t), lambda i, k: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((bp, s, t), jnp.float32),
        interpret=True,
    )(xp, yp)
    return out[:b]


def _tiled_kernel(x_ref, y_ref, o_ref, *, metric: str):
    """Grid = (M/BM, N/BN, D/BD). Blocks: x[BM,BD] y[BN,BD] o[BM,BN]."""
    k = pl.program_id(2)
    part = _tile_update(x_ref[...], y_ref[...], metric)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = part

    @pl.when(k != 0)
    def _acc():
        o_ref[...] += part


@functools.partial(
    jax.jit, static_argnames=("metric", "block_m", "block_n", "block_d")
)
def pairwise_tiled(
    x,
    y,
    metric: str = "l2",
    block_m: int = None,
    block_n: int = None,
    block_d: int = None,
):
    """Full pairwise distances: x[M,D], y[N,D] -> [M,N] f32.

    The brute-force building block (FAISS-BF baseline, ground truth).
    M and N are padded up to tile multiples; callers slice the result —
    padded *rows* are garbage but padded y-*columns* are the distance to
    the zero vector, so callers that top-k over the full padded axis must
    mask them (model.bruteforce does).

    Block defaults are whole-axis for the same interpret-mode reason as
    [`pairwise_batched`]; pass explicit blocks to exercise / project the
    real-TPU tiled schedule.
    """
    if metric not in METRICS:
        raise ValueError(f"unknown metric {metric!r}")
    m, d = x.shape
    n = y.shape[0]
    bm = min(block_m or _ceil_to(m, 8), _ceil_to(m, 8))
    bn = min(block_n or _ceil_to(n, 8), _ceil_to(n, 8))
    block_d = block_d or _ceil_to(d, 8)
    mp, np_, dp = _ceil_to(m, bm), _ceil_to(n, bn), _ceil_to(d, block_d)
    xp = _pad_last(x.astype(jnp.float32), dp)
    yp = _pad_last(y.astype(jnp.float32), dp)
    xp = jnp.pad(xp, ((0, mp - m), (0, 0)))
    yp = jnp.pad(yp, ((0, np_ - n), (0, 0)))
    grid = (mp // bm, np_ // bn, dp // block_d)
    out = pl.pallas_call(
        functools.partial(_tiled_kernel, metric=metric),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, block_d), lambda i, j, k: (i, k)),
            pl.BlockSpec((bn, block_d), lambda i, j, k: (j, k)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(xp, yp)
    return out[:m, :n]
