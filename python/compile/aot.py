"""AOT exporter: lower the L2 programs to HLO *text* + a manifest.

Interchange is HLO text, NOT a serialized HloModuleProto: jax >= 0.5 emits
protos with 64-bit instruction ids that the xla crate's xla_extension
0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Usage (from python/):  python -m compile.aot --out-dir ../artifacts

Emits one ``<name>.hlo.txt`` per spec plus ``manifest.txt`` — a line-based
``key=value`` format the Rust runtime parses without a JSON dependency:

    kind=crossmatch name=crossmatch_s32_d128_l2 metric=l2 impl=pallas \
        b=64 s=32 d=128 file=crossmatch_s32_d128_l2.hlo.txt

The default spec set covers the synthetic benchmark suite (DESIGN.md):
d in {32, 96, 100, 128, 960} for the sift/deep/glove/gist-shaped data,
sample widths S in {16, 32} (= 2p for p in {8, 16}), plus ``impl=jnp``
twins of the d=128 crossmatch for the L1 ablation bench.
"""

import argparse
import functools
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model

#: Batch of object locals per crossmatch call. Measured sweet spot for
#: the CPU PJRT client (§Perf runtime iteration 4 tried 256: XLA-side
#: cost rose to 57 us/object vs 31 us/object at 64 and serialized the
#: worker threads — reverted). B=64 keeps per-call XLA time ~2 ms while
#: the coordinator's worker threads dispatch concurrently.
CROSSMATCH_B = 64

#: Brute-force block shape (queries x base rows) and top-k width.
BF_Q, BF_N, BF_K = 256, 2048, 64


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the 0.5.1-safe path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def crossmatch_spec(s: int, d: int, metric: str, impl: str, b: int = CROSSMATCH_B):
    name = f"crossmatch_s{s}_d{d}_{metric}" + ("" if impl == "pallas" else f"_{impl}")
    fn = functools.partial(model.crossmatch, metric=metric, impl=impl)
    args = (
        jax.ShapeDtypeStruct((b, s, d), jnp.float32),
        jax.ShapeDtypeStruct((b, s), jnp.int32),
        jax.ShapeDtypeStruct((b, s, d), jnp.float32),
        jax.ShapeDtypeStruct((b, s), jnp.int32),
    )
    meta = dict(kind="crossmatch", name=name, metric=metric, impl=impl, b=b, s=s, d=d)
    return name, fn, args, meta


def bruteforce_spec(d: int, metric: str, impl: str = "pallas",
                    q: int = BF_Q, n: int = BF_N, k: int = BF_K):
    name = f"bruteforce_d{d}_{metric}" + ("" if impl == "pallas" else f"_{impl}")
    fn = functools.partial(model.bruteforce, k=k, metric=metric, impl=impl)
    args = (
        jax.ShapeDtypeStruct((q, d), jnp.float32),
        jax.ShapeDtypeStruct((n, d), jnp.float32),
        jax.ShapeDtypeStruct((n,), jnp.float32),
    )
    meta = dict(kind="bruteforce", name=name, metric=metric, impl=impl,
                q=q, n=n, d=d, k=k)
    return name, fn, args, meta


def default_specs():
    specs = []
    for s in (16, 32):
        for d in (32, 96, 128):
            specs.append(crossmatch_spec(s, d, "l2", "pallas"))
        specs.append(crossmatch_spec(s, 100, "ip", "pallas"))
        specs.append(crossmatch_spec(s, 960, "l2", "pallas"))
    # jnp twins for the L1 pallas-vs-plain-XLA ablation (bench: micro).
    specs.append(crossmatch_spec(32, 128, "l2", "jnp"))
    for d in (32, 96, 128, 960):
        specs.append(bruteforce_spec(d, "l2"))
    specs.append(bruteforce_spec(100, "ip"))
    return specs


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default=None,
                    help="comma-separated artifact-name substrings to build")
    args = ap.parse_args(argv)
    os.makedirs(args.out_dir, exist_ok=True)

    specs = default_specs()
    if args.only:
        keys = args.only.split(",")
        specs = [sp for sp in specs if any(k in sp[0] for k in keys)]

    manifest_lines = []
    for name, fn, shapes, meta in specs:
        lowered = jax.jit(fn).lower(*shapes)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(args.out_dir, fname), "w") as f:
            f.write(text)
        meta["file"] = fname
        line = " ".join(f"{k}={v}" for k, v in meta.items())
        manifest_lines.append(line)
        print(f"wrote {fname} ({len(text)} chars)", file=sys.stderr)

    manifest_path = os.path.join(args.out_dir, "manifest.txt")
    if args.only and os.path.exists(manifest_path):
        # partial rebuild: merge with existing entries (rebuilt names win)
        rebuilt = {line.split("name=")[1].split()[0] for line in manifest_lines}
        with open(manifest_path) as f:
            kept = [
                line.strip()
                for line in f
                if line.strip()
                and line.split("name=")[1].split()[0] not in rebuilt
            ]
        manifest_lines = kept + manifest_lines
    with open(manifest_path, "w") as f:
        f.write("\n".join(manifest_lines) + "\n")
    print(f"wrote manifest with {len(manifest_lines)} artifacts", file=sys.stderr)


if __name__ == "__main__":
    main()
