"""L2 — the jax compute graphs GNND executes on-device.

Two programs are AOT-lowered (python/compile/aot.py) and executed from the
Rust coordinator through PJRT; Python is never on the construction path.

``crossmatch``
    One GNND cross-matching step (paper §4.2 + Algorithm 2) for a batch of
    B object locals. Inputs are the gathered NEW / OLD sample vectors and
    their *group ids*; outputs are the Algorithm-2 nearest-object
    reductions the selective update consumes (paper §4.3). The group-id
    masking makes one artifact serve both modes:

    * normal construction — ids are global object ids: a pair is masked
      iff a slot is empty (id < 0) or both slots hold the same object
      (self-pairs, duplicate samples);
    * GGM merge (paper §5.1) — ids are *subset* ids: same-subset pairs
      are masked, so only cross-subgraph distances are computed, exactly
      the paper's restricted refinement.

``bruteforce``
    A (Q, N) exact distance block + top-k: the FAISS-BF baseline and the
    ground-truth generator.

Both call the L1 Pallas kernels; ``impl="jnp"`` swaps in the pure-jnp
reference (ref.py) so benches can ablate the tiled kernel against plain
XLA.
"""

import functools

import jax
import jax.numpy as jnp

from compile.kernels.pairwise import pairwise_batched, pairwise_tiled
from compile.kernels.ref import pairwise_ref

#: Finite "infinity" used for masked pairs. Keeping it finite (rather than
#: jnp.inf) means every lane stays well-defined under min/argmin on all
#: backends, and the Rust side can test `dist >= MASKED / 2` portably.
MASKED = jnp.float32(3.0e38)


def _pairwise(x, y, metric: str, impl: str):
    if impl == "pallas":
        return pairwise_batched(x, y, metric=metric)
    if impl == "jnp":
        return pairwise_ref(x, y, metric=metric)
    raise ValueError(f"unknown impl {impl!r}")


def _best(d, axis):
    """Masked argmin: returns (idx i32, dist f32), idx = -1 if no valid pair.

    One reduction (argmin) + a gather for the value — measurably cheaper
    on the CPU backend than separate min+argmin reductions (§Perf L2
    iteration 6).
    """
    bi = jnp.argmin(d, axis=axis).astype(jnp.int32)
    bd = jnp.take_along_axis(d, jnp.expand_dims(bi, axis), axis=axis).squeeze(axis)
    bi = jnp.where(bd < MASKED / 2, bi, jnp.int32(-1))
    return bi, bd


@functools.partial(jax.jit, static_argnames=("metric", "impl"))
def crossmatch(new_vecs, new_ids, old_vecs, old_ids, *, metric="l2", impl="pallas"):
    """One cross-matching step over a batch of B object locals.

    Args:
      new_vecs: f32[B, S, D] gathered NEW sample vectors.
      new_ids:  i32[B, S] group ids (object ids, or subset ids in merge
                mode); id < 0 marks an empty slot.
      old_vecs: f32[B, S, D] gathered OLD sample vectors.
      old_ids:  i32[B, S] likewise.

    Returns (all [B, S]):
      nn_idx, nn_dist — per NEW sample: nearest *other* NEW sample
                        (local column index into the NEW axis; -1 = none).
      no_idx, no_dist — per NEW sample: nearest OLD sample.
      on_idx, on_dist — per OLD sample: nearest NEW sample.
    """
    d_nn = _pairwise(new_vecs, new_vecs, metric, impl)
    d_no = _pairwise(new_vecs, old_vecs, metric, impl)

    valid_n = new_ids >= 0
    valid_o = old_ids >= 0
    ok_nn = (
        valid_n[:, :, None]
        & valid_n[:, None, :]
        & (new_ids[:, :, None] != new_ids[:, None, :])
    )
    ok_no = (
        valid_n[:, :, None]
        & valid_o[:, None, :]
        & (new_ids[:, :, None] != old_ids[:, None, :])
    )
    d_nn = jnp.where(ok_nn, d_nn, MASKED)
    d_no = jnp.where(ok_no, d_no, MASKED)

    nn_idx, nn_dist = _best(d_nn, 2)
    no_idx, no_dist = _best(d_no, 2)
    on_idx, on_dist = _best(d_no, 1)
    return nn_idx, nn_dist, no_idx, no_dist, on_idx, on_dist


@functools.partial(jax.jit, static_argnames=("k", "metric", "impl"))
def bruteforce(queries, base, base_valid, *, k=64, metric="l2", impl="pallas"):
    """Exact top-k of a (Q, N) block: the FAISS-BF / ground-truth program.

    Args:
      queries:    f32[Q, D].
      base:       f32[N, D].
      base_valid: f32[N], 1.0 for live rows, 0.0 for padding.

    Returns:
      idx  i32[Q, k] — base-row indices, -1 where fewer than k live rows.
      dist f32[Q, k] — ascending distances.
    """
    if impl == "pallas":
        d = pairwise_tiled(queries, base, metric=metric)
    else:
        d = pairwise_ref(queries, base, metric=metric)
    d = jnp.where(base_valid[None, :] > 0.5, d, MASKED)
    # NOTE: jax.lax.top_k lowers to an HLO `topk(..., largest=true)`
    # attribute that xla_extension 0.5.1's text parser rejects; a full
    # argsort lowers to the classic `sort` op, which round-trips.
    order = jnp.argsort(d, axis=-1)[:, :k].astype(jnp.int32)
    dist = jnp.take_along_axis(d, order, axis=-1)
    idx = jnp.where(dist < MASKED / 2, order, jnp.int32(-1))
    return idx, dist
